//! The PR 7 invariant linter: six line-lexical rules over the
//! code/comment split (see the crate docs in `main.rs` and
//! `rust/ANALYSIS.md` for rules and rationale).
//!
//! The raw (pre-suppression) findings are public: the stale-allow
//! analysis pass re-derives them to decide whether each
//! `lint:allow` annotation still suppresses anything.

use std::collections::BTreeSet;

use crate::allow::{allowed, parse_allow};
use crate::report::Finding;
use crate::splitter::{find_word, is_word, leading_ident, split_code_comment, trailing_ident, Split};

pub const KNOWN_RULES: [&str; 6] =
    ["hash-iter", "wall-clock", "atomic-ordering", "panic", "metrics-shim", "memo"];

/// Files where wall-clock reads are the point (latency measurement).
pub const WALL_CLOCK_ALLOW: [&str; 3] =
    ["util/trace.rs", "util/metrics.rs", "serving/serve_loop.rs"];

/// Lock-free layers whose atomics must justify their memory orderings.
pub const ORDERING_FILES: [&str; 5] =
    ["util/metrics.rs", "util/trace.rs", "util/threadpool.rs", "util/logging.rs", "util/version.rs"];

/// How far above an `Ordering::*` use a `// ordering:` note may sit
/// (block-style notes cover a whole match/loop/struct literal).
pub const ORDERING_WINDOW: usize = 12;

/// Deterministic layers: hash-order iteration is banned here.
pub const HASH_DET_DIRS: [&str; 3] = ["partition/", "scenario/", "graph/"];
pub const HASH_DET_FILES: [&str; 2] = ["drl/env.rs", "drl/vec_env.rs"];

const ITER_METHODS: [&str; 7] =
    ["iter", "iter_mut", "keys", "values", "values_mut", "drain", "into_iter"];

/// One rule hit before suppression filtering.  `line` is 0-based.
pub struct Raw {
    pub rule: &'static str,
    pub line: usize,
    pub msg: String,
}

/// The split, the `#[cfg(test)]` cutoff and every raw rule hit for one
/// source file.
pub struct LintScan {
    pub split: Split,
    pub end: usize,
    pub raw: Vec<Raw>,
}

/// First `#[cfg(test)]` line: everything below is test code and out of
/// scope for every rule and pass.
pub fn test_cutoff(s: &Split) -> usize {
    s.code
        .iter()
        .position(|c| c.contains("#[cfg(test)]"))
        .unwrap_or(s.code.len())
}

/// Collect names bound to hash containers on this line, from either
/// `let [mut] NAME = [std::collections::]Hash{Map,Set}::…` or the type
/// position `NAME: &mut Hash{Map,Set}<…>`.
fn hash_decl_names(code: &str, out: &mut BTreeSet<String>) {
    let mut from = 0;
    while let Some(at) = find_word(code, "let", from) {
        from = at + 3;
        let rest = &code[at + 3..];
        if !rest.starts_with(char::is_whitespace) {
            continue;
        }
        let rest = rest.trim_start();
        let rest = match rest.strip_prefix("mut") {
            Some(r) if r.starts_with(char::is_whitespace) => r.trim_start(),
            _ => rest,
        };
        let name = leading_ident(rest);
        if name.is_empty() {
            continue;
        }
        let after = rest[name.len()..].trim_start();
        let Some(after) = after.strip_prefix('=') else {
            continue;
        };
        let after = after.trim_start();
        let after = after.strip_prefix("std::collections::").unwrap_or(after);
        if after.starts_with("HashMap::") || after.starts_with("HashSet::") {
            out.insert(name.to_string());
        }
    }
    for ty in ["HashMap", "HashSet"] {
        let mut from = 0;
        while let Some(at) = find_word(code, ty, from) {
            from = at + ty.len();
            if !code[at + ty.len()..].trim_start().starts_with('<') {
                continue;
            }
            if let Some(name) = annotated_name_before(&code[..at]) {
                out.insert(name);
            }
        }
    }
}

/// For `NAME: &mut [std::collections::]Hash…<`, walk left from the
/// type token to recover `NAME`.
fn annotated_name_before(before: &str) -> Option<String> {
    let b = before.strip_suffix("std::collections::").unwrap_or(before);
    let b = b.trim_end();
    let b = match b.strip_suffix("mut") {
        Some(r) if !r.chars().next_back().is_some_and(is_word) => r.trim_end(),
        _ => b,
    };
    let b = b.strip_suffix('&').unwrap_or(b);
    let b = b.trim_end();
    let b = b.strip_suffix(':')?;
    let name = trailing_ident(b.trim_end());
    if name.is_empty() {
        None
    } else {
        Some(name.to_string())
    }
}

/// `NAME.iter()` / `.keys()` / … on a tracked hash container.
fn hash_iter_use(code: &str, tracked: &BTreeSet<String>) -> Option<String> {
    for name in tracked {
        let mut from = 0;
        while let Some(at) = find_word(code, name, from) {
            from = at + name.len();
            let rest = code[at + name.len()..].trim_start();
            let Some(rest) = rest.strip_prefix('.') else {
                continue;
            };
            let rest = rest.trim_start();
            let method = leading_ident(rest);
            if ITER_METHODS.contains(&method)
                && rest[method.len()..].trim_start().starts_with('(')
            {
                return Some(name.clone());
            }
        }
    }
    None
}

/// `for … in [&][mut ][self.]NAME` over a tracked hash container.
/// Returns `None` when the loop target continues into a method chain —
/// that case is [`hash_iter_use`]'s to judge.
fn hash_for_loop(code: &str, tracked: &BTreeSet<String>) -> Option<String> {
    let mut from = 0;
    while let Some(fat) = find_word(code, "for", from) {
        from = fat + 3;
        let Some(iat) = find_word(code, "in", fat + 3) else {
            continue;
        };
        let between = &code[fat + 3..iat];
        if between.contains(';') || between.contains('{') {
            continue;
        }
        let rest = &code[iat + 2..];
        if !rest.starts_with(char::is_whitespace) {
            continue;
        }
        let rest = rest.trim_start();
        let rest = rest.strip_prefix('&').unwrap_or(rest);
        let rest = match rest.strip_prefix("mut") {
            Some(r) if r.starts_with(char::is_whitespace) => r.trim_start(),
            _ => rest,
        };
        let rest = match rest.strip_prefix("self") {
            Some(r) if !r.starts_with(is_word) => match r.trim_start().strip_prefix('.') {
                Some(r2) => r2.trim_start(),
                None => rest,
            },
            _ => rest,
        };
        let name = leading_ident(rest);
        if !tracked.contains(name) {
            continue;
        }
        if rest[name.len()..].trim_start().starts_with('.') {
            continue;
        }
        return Some(name.to_string());
    }
    None
}

/// A string-keyed call on the metrics shim (`METRICS.observe(…)` etc.).
fn metrics_shim_call(code: &str) -> bool {
    for recv in ["METRICS", "GLOBAL"] {
        let mut from = 0;
        while let Some(at) = find_word(code, recv, from) {
            from = at + recv.len();
            let rest = code[at + recv.len()..].trim_start();
            let Some(rest) = rest.strip_prefix('.') else {
                continue;
            };
            let rest = rest.trim_start();
            let method = leading_ident(rest);
            if ["observe", "inc", "add", "set_gauge", "time"].contains(&method)
                && rest[method.len()..].trim_start().starts_with('(')
            {
                return true;
            }
        }
    }
    false
}

/// Run every rule over one file and keep the hits *unfiltered* — the
/// caller decides whether `lint:allow` suppression applies.
pub fn lint_scan(rel: &str, src: &str) -> LintScan {
    let s = split_code_comment(src);
    let end = test_cutoff(&s);
    let mut raw: Vec<Raw> = Vec::new();
    let mut push = |rule: &'static str, line: usize, msg: String| {
        raw.push(Raw { rule, line, msg });
    };

    // -- allow-syntax: a malformed escape hatch is itself a finding --
    // (Gated on the opening paren so prose mentions of `lint:allow`
    // in doc comments are not treated as annotations.)
    for (i, comment) in s.comment[..end].iter().enumerate() {
        if !comment.contains("lint:allow(") {
            continue;
        }
        match parse_allow(comment) {
            Some((rule, true)) if KNOWN_RULES.contains(&rule.as_str()) => {}
            Some((rule, true)) => {
                push("allow-syntax", i, format!("lint:allow names unknown rule `{rule}`"));
            }
            _ => push(
                "allow-syntax",
                i,
                "malformed allow: need `lint:allow(<rule>) — <reason>`".to_string(),
            ),
        }
    }

    // -- hash-iter ----------------------------------------------------
    let det_scope =
        HASH_DET_FILES.contains(&rel) || HASH_DET_DIRS.iter().any(|d| rel.starts_with(d));
    if det_scope {
        let mut tracked = BTreeSet::new();
        for code in &s.code[..end] {
            hash_decl_names(code, &mut tracked);
        }
        if !tracked.is_empty() {
            for i in 0..end {
                let code = &s.code[i];
                let sorted_near = code.contains("BTree")
                    || code.contains(".sort")
                    || (i + 1 < end && s.code[i + 1].contains(".sort"));
                if let Some(name) = hash_iter_use(code, &tracked) {
                    if !sorted_near {
                        let msg = format!(
                            "iteration over hash container `{name}` in a deterministic layer"
                        );
                        push("hash-iter", i, msg);
                    }
                    continue;
                }
                if let Some(name) = hash_for_loop(code, &tracked) {
                    if !sorted_near {
                        let msg = format!(
                            "for-loop over hash container `{name}` in a deterministic layer"
                        );
                        push("hash-iter", i, msg);
                    }
                }
            }
        }
    }

    // -- wall-clock ---------------------------------------------------
    if !WALL_CLOCK_ALLOW.contains(&rel) {
        for (i, code) in s.code[..end].iter().enumerate() {
            if code.contains("Instant::now") || find_word(code, "SystemTime", 0).is_some() {
                push(
                    "wall-clock",
                    i,
                    "wall-clock read outside trace/metrics/serve loop".to_string(),
                );
            }
        }
    }

    // -- atomic-ordering ----------------------------------------------
    if ORDERING_FILES.contains(&rel) {
        for i in 0..end {
            if !s.code[i].contains("Ordering::") {
                continue;
            }
            let lo = i.saturating_sub(ORDERING_WINDOW);
            if !s.comment[lo..=i].iter().any(|c| c.contains("ordering:")) {
                push(
                    "atomic-ordering",
                    i,
                    "atomic ordering without an adjacent `// ordering:` note".to_string(),
                );
            }
        }
    }

    // -- panic --------------------------------------------------------
    if rel.starts_with("serving/") || rel.starts_with("partition/") {
        for (i, code) in s.code[..end].iter().enumerate() {
            if code.contains(".unwrap()") || code.contains(".expect(") {
                push(
                    "panic",
                    i,
                    "unwrap/expect in serving/partition non-test code".to_string(),
                );
            }
        }
    }

    // -- memo ---------------------------------------------------------
    // `util/version.rs` hosts the one sanctioned memo cell; everywhere
    // else a `RefCell<Option<…>>` is an unversioned cache in disguise.
    if rel != "util/version.rs" {
        for (i, code) in s.code[..end].iter().enumerate() {
            if code.contains("RefCell<Option<") || code.contains("Cell<Option<") {
                push(
                    "memo",
                    i,
                    "hand-rolled memo cell; use util::version::Memoized".to_string(),
                );
            }
        }
    }

    // -- metrics-shim -------------------------------------------------
    // Brace-depth scan; a `for`/`while`/`loop` keyword arms the next
    // `{` as a loop body (`;` disarms — `for` in a doc path or a
    // statement boundary in between means it was not a loop header).
    let mut depth: i64 = 0;
    let mut loop_depths: Vec<i64> = Vec::new();
    let mut pending = false;
    for i in 0..end {
        let code = &s.code[i];
        if !loop_depths.is_empty() && metrics_shim_call(code) {
            push(
                "metrics-shim",
                i,
                "string-keyed metrics call inside a loop body".to_string(),
            );
        }
        let cv: Vec<char> = code.chars().collect();
        let mut j = 0;
        while j < cv.len() {
            let c = cv[j];
            if is_word(c) {
                let k0 = j;
                while j < cv.len() && is_word(cv[j]) {
                    j += 1;
                }
                let word: String = cv[k0..j].iter().collect();
                if matches!(word.as_str(), "for" | "while" | "loop") {
                    pending = true;
                }
                continue;
            }
            match c {
                ';' => pending = false,
                '{' => {
                    if pending {
                        loop_depths.push(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if loop_depths.last() == Some(&depth) {
                        loop_depths.pop();
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }

    LintScan { split: s, end, raw }
}

/// The linter proper: raw hits minus the `lint:allow`-suppressed ones.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let scan = lint_scan(rel, src);
    scan.raw
        .into_iter()
        .filter(|r| r.rule == "allow-syntax" || !allowed(r.rule, r.line, &scan.split))
        .map(|r| Finding { rule: r.rule, file: rel.to_string(), line: r.line + 1, msg: r.msg })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count(rel: &str, src: &str, rule: &str) -> usize {
        lint_source(rel, src).iter().filter(|f| f.rule == rule).count()
    }

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        let mut rs: Vec<&'static str> = lint_source(rel, src).iter().map(|f| f.rule).collect();
        rs.sort_unstable();
        rs.dedup();
        rs
    }

    const HASH_ITER_BAD: &str = include_str!("../fixtures/hash_iter_bad.rs");
    const HASH_ITER_ALLOWED: &str = include_str!("../fixtures/hash_iter_allowed.rs");
    const HASH_ITER_SORTED: &str = include_str!("../fixtures/hash_iter_sorted.rs");
    const WALL_CLOCK_BAD: &str = include_str!("../fixtures/wall_clock_bad.rs");
    const WALL_CLOCK_ALLOWED: &str = include_str!("../fixtures/wall_clock_allowed.rs");
    const ORDERING_BAD: &str = include_str!("../fixtures/ordering_bad.rs");
    const ORDERING_OK: &str = include_str!("../fixtures/ordering_ok.rs");
    const PANIC_BAD: &str = include_str!("../fixtures/panic_bad.rs");
    const PANIC_ALLOWED: &str = include_str!("../fixtures/panic_allowed.rs");
    const METRICS_LOOP_BAD: &str = include_str!("../fixtures/metrics_loop_bad.rs");
    const METRICS_LOOP_ALLOWED: &str = include_str!("../fixtures/metrics_loop_allowed.rs");
    const ALLOW_SYNTAX_BAD: &str = include_str!("../fixtures/allow_syntax_bad.rs");
    const MEMO_BAD: &str = include_str!("../fixtures/memo_bad.rs");
    const MEMO_ALLOWED: &str = include_str!("../fixtures/memo_allowed.rs");
    const SPLITTER_EDGES_OK: &str = include_str!("../fixtures/splitter_edges_ok.rs");
    const SPLITTER_EDGES_BAD: &str = include_str!("../fixtures/splitter_edges_bad.rs");

    #[test]
    fn hash_iter_fires_in_deterministic_layers() {
        assert_eq!(count("partition/fixture.rs", HASH_ITER_BAD, "hash-iter"), 2);
        assert_eq!(count("drl/env.rs", HASH_ITER_BAD, "hash-iter"), 2);
        assert_eq!(count("graph/fixture.rs", HASH_ITER_BAD, "hash-iter"), 2);
    }

    #[test]
    fn hash_iter_is_scoped_to_deterministic_layers() {
        assert_eq!(count("serving/fixture.rs", HASH_ITER_BAD, "hash-iter"), 0);
        assert_eq!(count("util/fixture.rs", HASH_ITER_BAD, "hash-iter"), 0);
        assert_eq!(count("drl/maddpg.rs", HASH_ITER_BAD, "hash-iter"), 0);
    }

    #[test]
    fn hash_iter_allow_annotation_suppresses() {
        assert_eq!(count("partition/fixture.rs", HASH_ITER_ALLOWED, "hash-iter"), 0);
    }

    #[test]
    fn hash_iter_sorted_use_is_exonerated() {
        assert_eq!(count("partition/fixture.rs", HASH_ITER_SORTED, "hash-iter"), 0);
    }

    #[test]
    fn wall_clock_fires_outside_the_allowed_files() {
        assert_eq!(count("drl/fixture.rs", WALL_CLOCK_BAD, "wall-clock"), 1);
        assert_eq!(count("partition/hicut.rs", WALL_CLOCK_BAD, "wall-clock"), 1);
    }

    #[test]
    fn wall_clock_allowed_files_and_annotations() {
        assert_eq!(count("util/trace.rs", WALL_CLOCK_BAD, "wall-clock"), 0);
        assert_eq!(count("util/metrics.rs", WALL_CLOCK_BAD, "wall-clock"), 0);
        assert_eq!(count("serving/serve_loop.rs", WALL_CLOCK_BAD, "wall-clock"), 0);
        assert_eq!(count("drl/fixture.rs", WALL_CLOCK_ALLOWED, "wall-clock"), 0);
    }

    #[test]
    fn ordering_note_required_and_sufficient() {
        assert_eq!(count("util/metrics.rs", ORDERING_BAD, "atomic-ordering"), 1);
        assert_eq!(count("util/threadpool.rs", ORDERING_BAD, "atomic-ordering"), 1);
        assert_eq!(count("util/metrics.rs", ORDERING_OK, "atomic-ordering"), 0);
        // The audit only covers the lock-free util files.
        assert_eq!(count("drl/fixture.rs", ORDERING_BAD, "atomic-ordering"), 0);
    }

    #[test]
    fn panic_rule_skips_test_modules_and_honors_allow() {
        assert_eq!(count("serving/fixture.rs", PANIC_BAD, "panic"), 1);
        assert_eq!(count("partition/fixture.rs", PANIC_BAD, "panic"), 1);
        assert_eq!(count("util/fixture.rs", PANIC_BAD, "panic"), 0);
        assert_eq!(count("serving/fixture.rs", PANIC_ALLOWED, "panic"), 0);
    }

    #[test]
    fn metrics_shim_only_fires_inside_loop_bodies() {
        assert_eq!(count("runtime/mod.rs", METRICS_LOOP_BAD, "metrics-shim"), 1);
        assert_eq!(count("runtime/mod.rs", METRICS_LOOP_ALLOWED, "metrics-shim"), 0);
    }

    #[test]
    fn memo_fires_everywhere_except_the_substrate_file() {
        // Both cell shapes, once each; the `#[cfg(test)]` module with a
        // third cell is exempt.
        assert_eq!(count("util/stats.rs", MEMO_BAD, "memo"), 2);
        assert_eq!(count("drl/env.rs", MEMO_BAD, "memo"), 2);
        assert_eq!(count("util/version.rs", MEMO_BAD, "memo"), 0);
        assert_eq!(count("util/trace.rs", MEMO_ALLOWED, "memo"), 0);
    }

    #[test]
    fn malformed_allow_is_reported_and_does_not_suppress() {
        assert_eq!(count("drl/fixture.rs", ALLOW_SYNTAX_BAD, "allow-syntax"), 1);
        assert_eq!(count("drl/fixture.rs", ALLOW_SYNTAX_BAD, "wall-clock"), 1);
    }

    #[test]
    fn splitter_edge_cases_never_leak_into_code() {
        // Nested block comments, a raw string with hashes, lifetime
        // ticks vs char literals, and a `#[cfg(test)]` module — every
        // banned token sits in an opaque region and nothing may fire.
        assert!(rules("partition/fixture.rs", SPLITTER_EDGES_OK).is_empty());
    }

    #[test]
    fn splitter_edge_cases_fire_outside_the_opaque_regions() {
        // The firing twin: the same constructs with the tokens just
        // outside the literals/comments/test module.
        assert_eq!(count("partition/fixture.rs", SPLITTER_EDGES_BAD, "panic"), 3);
        assert_eq!(count("partition/fixture.rs", SPLITTER_EDGES_BAD, "wall-clock"), 1);
        assert_eq!(
            rules("partition/fixture.rs", SPLITTER_EDGES_BAD),
            vec!["panic", "wall-clock"]
        );
    }

    #[test]
    fn strings_and_comments_are_not_code() {
        let src = concat!(
            "pub fn f() -> &'static str {\n",
            "    \"Instant::now()\"\n",
            "}\n",
            "// SystemTime in prose only\n",
        );
        assert!(rules("drl/fixture.rs", src).is_empty());
    }

    #[test]
    fn allow_grammar_accepts_the_three_dash_forms() {
        for dash in ["—", "--", "-"] {
            let src = format!(
                "pub fn f() {{\n    // lint:allow(wall-clock) {dash} reason.\n    \
                 let _t = std::time::Instant::now();\n}}\n"
            );
            assert_eq!(count("drl/fixture.rs", &src, "wall-clock"), 0, "dash {dash:?}");
        }
    }

    #[test]
    fn unknown_rule_in_allow_is_reported() {
        let src = "// lint:allow(no-such-rule) — typo.\npub fn f() {}\n";
        assert_eq!(count("drl/fixture.rs", src, "allow-syntax"), 1);
    }
}
