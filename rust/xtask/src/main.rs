//! `xtask` — repo-local developer tooling for the GraphEdge crate.
//!
//! Two subcommands, one output contract:
//!
//! ```text
//! cargo run -p xtask -- lint    [SRC_DIR] [--format text|json]
//! cargo run -p xtask -- analyze [SRC_DIR] [--format text|json]
//! ```
//!
//! `SRC_DIR` defaults to `rust/src`.  Both emit findings sorted by
//! (file, line, rule) with the stable machine-readable prefix
//! `file:line:rule: message`, and `--format json` produces a single
//! JSON object for CI artifact upload/diffing.  Exit codes: 0 clean,
//! 1 findings, 2 usage/IO errors.
//!
//! **`lint`** is the line-lexical invariant pass (PR 7): six rules —
//! `hash-iter`, `wall-clock`, `atomic-ordering`, `panic` (unwrap/
//! expect), `metrics-shim`, `memo` — scoped by path, with the
//! `// lint:allow(<rule>) — <reason>` escape hatch.  See `lint.rs`.
//!
//! **`analyze`** is the semantic pass built on a lightweight item
//! model (fns/impl methods with brace-matched bodies plus a
//! name-based intra-crate call graph): `version` (version-stamp
//! soundness for the producers and `Memoized` consumers of
//! `util::version`), `panic` (transitive panic-freedom for `serving/`
//! + `partition/`, with call chains in the report) and `stale-allow`
//! (escape hatches whose rule no longer fires).  Escape hatch:
//! `// analyze:allow(<rule>[: <callee>]) — <reason>`.  See
//! `analyze.rs`.
//!
//! Both passes are deliberately dependency-free: the offline build
//! environment cannot fetch `syn`, so everything stands on a per-line
//! code/comment split (`splitter.rs`) that tracks strings, raw
//! strings, char literals and nested block comments.  Design, grammar
//! and known lexical limitations live in `rust/ANALYSIS.md`.

mod allow;
mod analyze;
mod items;
mod lint;
mod report;
mod splitter;

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use report::{render_json, render_text, sort_findings, Finding, Format};

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if entry.file_type()?.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Read every `.rs` under `root` as (rel path with `/` separators,
/// source), sorted by path.
fn read_tree(root: &Path) -> std::io::Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    files.sort();
    let mut out = Vec::with_capacity(files.len());
    for path in files {
        let rel =
            path.strip_prefix(root).unwrap_or(&path).to_string_lossy().replace('\\', "/");
        out.push((rel, std::fs::read_to_string(&path)?));
    }
    Ok(out)
}

/// The shipped crate sources (`rust/src`), for the self-tests that
/// re-analyze the real tree on every `cargo test -p xtask`.
#[cfg(test)]
fn tree_sources() -> Vec<(String, String)> {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src");
    read_tree(&root).expect("walk rust/src")
}

fn default_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("src")
}

fn emit(tool: &str, files: usize, mut findings: Vec<Finding>, format: Format) -> ExitCode {
    sort_findings(&mut findings);
    match format {
        Format::Text => {
            print!("{}", render_text(&findings));
            if findings.is_empty() {
                println!("{tool}: clean ({files} files)");
            } else {
                println!("{tool}: {} finding(s)", findings.len());
            }
        }
        Format::Json => print!("{}", render_json(tool, files, &findings)),
    }
    if findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn run(cmd: &str, root: Option<PathBuf>, format: Format) -> ExitCode {
    let root = root.unwrap_or_else(default_root);
    let files = match read_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("xtask {cmd}: cannot walk {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    let findings = match cmd {
        "lint" => {
            files.iter().flat_map(|(rel, src)| lint::lint_source(rel, src)).collect()
        }
        _ => analyze::analyze_tree(&files),
    };
    emit(&format!("xtask-{cmd}"), files.len(), findings, format)
}

fn usage() -> ExitCode {
    eprintln!("usage: cargo run -p xtask -- <lint|analyze> [SRC_DIR] [--format text|json]");
    ExitCode::from(2)
}

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = args.first().map(String::as_str) else {
        return usage();
    };
    if cmd != "lint" && cmd != "analyze" {
        return usage();
    }
    let mut root: Option<PathBuf> = None;
    let mut format = Format::Text;
    let mut i = 1;
    while i < args.len() {
        match args[i].as_str() {
            "--format" => {
                match args.get(i + 1).map(String::as_str) {
                    Some("text") => format = Format::Text,
                    Some("json") => format = Format::Json,
                    _ => return usage(),
                }
                i += 2;
            }
            "--format=text" => {
                format = Format::Text;
                i += 1;
            }
            "--format=json" => {
                format = Format::Json;
                i += 1;
            }
            flag if flag.starts_with('-') => return usage(),
            dir => {
                if root.replace(PathBuf::from(dir)).is_some() {
                    return usage();
                }
                i += 1;
            }
        }
    }
    run(cmd, root, format)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The linter's reason to exist: the shipped tree must be clean.
    /// This doubles as a check that the walker and every rule agree
    /// with the real codebase, not just the fixtures.
    #[test]
    fn the_real_tree_is_clean() {
        let findings: Vec<Finding> = tree_sources()
            .iter()
            .flat_map(|(rel, src)| lint::lint_source(rel, src))
            .collect();
        assert!(findings.is_empty(), "lint findings in rust/src: {findings:#?}");
    }
}
