"""DRL train-step math: MADDPG + PPO invariants before AOT lowering."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile import drl

RNG = np.random.default_rng(7)


def init_all(seed=0):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 2 * drl.M)
    actor = jnp.stack([drl.init_mlp(keys[i], drl.ACTOR_SHAPES)
                       for i in range(drl.M)])
    critic = jnp.stack([drl.init_mlp(keys[drl.M + i], drl.CRITIC_SHAPES)
                        for i in range(drl.M)])
    return actor, critic


def fake_batch(b=drl.BATCH):
    return dict(
        s=jnp.asarray(RNG.normal(size=(b, drl.STATE)).astype(np.float32)),
        a=jnp.asarray(RNG.random((b, drl.M, drl.ACT)).astype(np.float32)),
        r=jnp.asarray(RNG.normal(size=(b, drl.M)).astype(np.float32)),
        s2=jnp.asarray(RNG.normal(size=(b, drl.STATE)).astype(np.float32)),
        done=jnp.asarray((RNG.random((b, drl.M)) < 0.1).astype(np.float32)),
        obs=jnp.asarray(RNG.normal(size=(b, drl.M, drl.OBS)).astype(np.float32)),
        obs2=jnp.asarray(RNG.normal(size=(b, drl.M, drl.OBS)).astype(np.float32)),
    )


def test_flat_sizes():
    assert drl.P_ACTOR == sum(int(np.prod(s)) for s in drl.ACTOR_SHAPES)
    assert drl.P_CRITIC == sum(int(np.prod(s)) for s in drl.CRITIC_SHAPES)
    # in->64, 64->64, 64->64, 64->out plus biases
    assert drl.ACTOR_SHAPES[0] == (drl.OBS, drl.HID)
    assert drl.CRITIC_SHAPES[0] == (drl.STATE + drl.M * drl.ACT, drl.HID)


def test_unflatten_round_trip():
    flat = jnp.arange(drl.P_ACTOR, dtype=jnp.float32)
    parts = drl.unflatten(flat, drl.ACTOR_SHAPES)
    rebuilt = jnp.concatenate([p.reshape(-1) for p in parts])
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(rebuilt))


def test_actor_outputs_in_unit_interval():
    actor, _ = init_all()
    obs = jnp.asarray(RNG.normal(size=(drl.M, drl.OBS), scale=5).astype(np.float32))
    (acts,) = drl.actor_fwd(actor, obs)
    assert acts.shape == (drl.M, drl.ACT)
    a = np.asarray(acts)
    assert np.all(a >= 0.0) and np.all(a <= 1.0)


def test_critic_scalar_output():
    _, critic = init_all()
    s = jnp.zeros((5, drl.STATE))
    a = jnp.zeros((5, drl.M * drl.ACT))
    q = drl.critic_apply(critic[0], s, a)
    assert q.shape == (5,)


def test_maddpg_train_step_shapes_and_finiteness():
    actor, critic = init_all()
    b = fake_batch()
    out = drl.maddpg_train(
        actor, critic, actor, critic,
        jnp.zeros_like(actor), jnp.zeros_like(actor),
        jnp.zeros_like(critic), jnp.zeros_like(critic),
        jnp.asarray(0.0),
        b["s"], b["a"], b["r"], b["s2"], b["done"], b["obs"], b["obs2"],
    )
    (actor2, critic2, ta2, tc2, ma, va, mc, vc, step, closs, aloss) = out
    assert actor2.shape == actor.shape and critic2.shape == critic.shape
    assert float(step) == 1.0
    for t in out:
        assert np.all(np.isfinite(np.asarray(t)))
    # Parameters actually moved.
    assert not np.allclose(np.asarray(actor2), np.asarray(actor))
    assert not np.allclose(np.asarray(critic2), np.asarray(critic))


def test_maddpg_soft_update_is_tau_blend():
    actor, critic = init_all()
    b = fake_batch(b=drl.BATCH)
    t_actor = actor + 1.0  # distinct targets to observe the blend
    out = drl.maddpg_train(
        actor, critic, t_actor, critic,
        jnp.zeros_like(actor), jnp.zeros_like(actor),
        jnp.zeros_like(critic), jnp.zeros_like(critic),
        jnp.asarray(0.0),
        b["s"], b["a"], b["r"], b["s2"], b["done"], b["obs"], b["obs2"],
    )
    actor2, ta2 = out[0], out[2]
    expect = drl.TAU * np.asarray(actor2) + (1 - drl.TAU) * np.asarray(t_actor)
    np.testing.assert_allclose(np.asarray(ta2), expect, rtol=1e-5, atol=1e-6)


def test_maddpg_done_masks_bootstrap():
    """With done=1 everywhere and zero rewards the TD target is 0, so the
    critic loss equals mean Q^2 — check against a manual computation."""
    actor, critic = init_all()
    b = fake_batch()
    done = jnp.ones_like(b["done"])
    r = jnp.zeros_like(b["r"])
    out = drl.maddpg_train(
        actor, critic, actor, critic,
        jnp.zeros_like(actor), jnp.zeros_like(actor),
        jnp.zeros_like(critic), jnp.zeros_like(critic),
        jnp.asarray(0.0),
        b["s"], b["a"], r, b["s2"], done, b["obs"], b["obs2"],
    )
    closs = np.asarray(out[9])
    a_flat = b["a"].reshape(drl.BATCH, drl.M * drl.ACT)
    for m in range(drl.M):
        q = np.asarray(drl.critic_apply(critic[m], b["s"], a_flat))
        np.testing.assert_allclose(closs[m], np.mean(q ** 2), rtol=1e-4)


def test_ppo_fwd_shapes():
    p = drl.init_mlp(jax.random.PRNGKey(3), drl.PPO_SHAPES)
    s = jnp.zeros((1, drl.STATE))
    logits, value = drl.ppo_fwd(p, s)
    assert logits.shape == (1, drl.PPO_ACTIONS)
    assert value.shape == (1,)


def test_ppo_train_improves_chosen_action_prob():
    """With positive advantage on one action, its probability rises."""
    p = drl.init_mlp(jax.random.PRNGKey(4), drl.PPO_SHAPES)
    b = drl.BATCH
    s = jnp.asarray(RNG.normal(size=(b, drl.STATE)).astype(np.float32))
    onehot = np.zeros((b, drl.M), dtype=np.float32)
    onehot[:, 1] = 1.0
    logits, _ = drl.ppo_fwd(p, s)
    logp_all = np.asarray(jax.nn.log_softmax(logits, axis=-1))
    old_logp = jnp.asarray(logp_all[:, 1])
    adv = jnp.ones((b,), jnp.float32)
    ret = jnp.zeros((b,), jnp.float32)
    p2 = p
    for _ in range(20):
        p2, m2, v2, *_ = drl.ppo_train(
            p2, jnp.zeros_like(p), jnp.zeros_like(p), jnp.asarray(0.0),
            s, jnp.asarray(onehot), old_logp, adv, ret)
    logits2, _ = drl.ppo_fwd(p2, s)
    new = np.asarray(jax.nn.log_softmax(logits2, axis=-1))[:, 1]
    assert new.mean() > logp_all[:, 1].mean()


def test_ppo_train_outputs_finite():
    p = drl.init_mlp(jax.random.PRNGKey(5), drl.PPO_SHAPES)
    b = drl.BATCH
    out = drl.ppo_train(
        p, jnp.zeros_like(p), jnp.zeros_like(p), jnp.asarray(0.0),
        jnp.zeros((b, drl.STATE)), jnp.ones((b, drl.M)) / drl.M,
        jnp.zeros((b,)), jnp.zeros((b,)), jnp.zeros((b,)))
    for t in out:
        assert np.all(np.isfinite(np.asarray(t)))


def test_adam_reduces_quadratic():
    """Sanity: the shared Adam update drives a quadratic toward 0."""
    p = jnp.asarray([1.0, -0.4])
    m = jnp.zeros_like(p)
    v = jnp.zeros_like(p)
    for t in range(1, 4000):
        g = 2.0 * p
        p, m, v = drl.adam_update(p, g, m, v, float(t))
    # lr is Table 2's 3e-4, so convergence is slow but monotone toward 0.
    assert float(jnp.max(jnp.abs(p))) < 0.15
