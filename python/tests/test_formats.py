"""GTA / GEB binary format round-trips and dataset-generator invariants."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import data as data_mod
from compile.gta import read_gta, write_gta


# ---------------------------------------------------------------------------
# GTA
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(shapes=st.lists(
    st.lists(st.integers(1, 7), min_size=0, max_size=3), min_size=1,
    max_size=5),
    seed=st.integers(0, 2**31 - 1))
def test_gta_round_trip(shapes, seed):
    import tempfile
    from pathlib import Path
    rng = np.random.default_rng(seed)
    tensors = []
    for i, s in enumerate(shapes):
        if i % 3 == 2:
            arr = rng.integers(-100, 100, size=s).astype(np.int64)
        else:
            arr = rng.normal(size=s).astype(np.float32)
        tensors.append((f"t{i}", arr))
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "x.gta"
        write_gta(path, tensors)
        back = read_gta(path)
    assert [n for n, _ in back] == [n for n, _ in tensors]
    for (_, a), (_, b) in zip(tensors, back):
        if np.issubdtype(a.dtype, np.integer):
            np.testing.assert_array_equal(a.astype(np.int32), b)
        else:
            np.testing.assert_array_equal(a.astype(np.float32), b)


def test_gta_scalar(tmp_path):
    write_gta(tmp_path / "s.gta", [("step", np.float32(3.0))])
    [(name, arr)] = read_gta(tmp_path / "s.gta")
    assert name == "step" and arr.shape == () and float(arr) == 3.0


def test_gta_bad_magic(tmp_path):
    p = tmp_path / "bad.gta"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(AssertionError):
        read_gta(p)


# ---------------------------------------------------------------------------
# GEB + generator
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_dataset():
    # Shrink the spec for test speed but keep the real generator path.
    spec = data_mod.SPECS.copy()
    data_mod.SPECS["_test"] = (400, 1200, 128, 4)
    try:
        d = data_mod.generate("_test", seed=123)
    finally:
        data_mod.SPECS = spec
    return d


def test_generator_matches_spec(small_dataset):
    d = small_dataset
    assert d["n"] == 400 and d["e"] == 1200
    assert d["labels"].shape == (400,)
    assert d["labels"].max() < 4
    assert d["row_ptr"].shape == (401,)
    assert int(d["row_ptr"][-1]) == len(d["col_idx"])


def test_generator_edges_valid(small_dataset):
    e = small_dataset["edges"]
    assert e.shape == (1200, 2)
    assert np.all(e[:, 0] < e[:, 1])          # canonical order, no loops
    assert np.all(e < 400)
    assert len({tuple(r) for r in e.tolist()}) == 1200  # no duplicates


def test_generator_heavy_tail(small_dataset):
    """Preferential attachment → max degree well above the mean (Fig. 5)."""
    deg = np.zeros(400, dtype=int)
    for u, v in small_dataset["edges"]:
        deg[u] += 1
        deg[v] += 1
    assert deg.max() >= 4 * deg.mean()


def test_generator_deterministic():
    spec = data_mod.SPECS.copy()
    data_mod.SPECS["_t2"] = (150, 300, 64, 3)
    try:
        a = data_mod.generate("_t2", seed=5)
        b = data_mod.generate("_t2", seed=5)
    finally:
        data_mod.SPECS = spec
    np.testing.assert_array_equal(a["edges"], b["edges"])
    np.testing.assert_array_equal(a["col_idx"], b["col_idx"])


def test_geb_round_trip(tmp_path, small_dataset):
    path = tmp_path / "d.geb"
    data_mod.write_geb(path, small_dataset)
    back = data_mod.read_geb(path)
    for k in ("n", "e", "f", "c"):
        assert back[k] == small_dataset[k]
    np.testing.assert_array_equal(back["labels"], small_dataset["labels"])
    np.testing.assert_array_equal(back["edges"], small_dataset["edges"])
    np.testing.assert_array_equal(back["col_idx"], small_dataset["col_idx"])


def test_dense_features_normalized(small_dataset):
    x = data_mod.dense_features(small_dataset, 128, rows=range(50))
    norms = np.linalg.norm(x, axis=1)
    np.testing.assert_allclose(norms, 1.0, rtol=1e-5)


def test_features_class_correlated(small_dataset):
    """Same-class documents share more words than cross-class ones —
    the homophily that lets GNNs reach the paper's accuracy band."""
    d = small_dataset
    x = data_mod.dense_features(d, 128, rows=range(200))
    sims = x @ x.T
    same, diff = [], []
    lab = d["labels"][:200]
    for i in range(0, 200, 7):
        for j in range(i + 1, 200, 11):
            (same if lab[i] == lab[j] else diff).append(sims[i, j])
    # Signatures deliberately overlap ~50% (keeps pre-training in the
    # paper's 60-80% band), so the margin is modest but must be real.
    assert np.mean(same) > np.mean(diff) * 1.05
