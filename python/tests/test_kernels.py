"""L1 correctness: Pallas kernels vs the pure-jnp oracle.

Hypothesis sweeps shapes (within the tiling contract: dims that the
block-picker can tile) and values; assert_allclose against ref.*.
This suite is the core correctness signal for the serving hot path —
pre-training differentiates through ref.* while serving executes the
Pallas HLO, and these tests are what make those interchangeable.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import (
    attn_scores,
    masked_softmax,
    matmul,
    matmul_bias_act,
    mean_agg,
    pick_block,
    ref,
)

DIMS = st.sampled_from([8, 16, 32, 64, 128, 192, 320])
SMALL_DIMS = st.sampled_from([1, 2, 8, 64])


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(size=shape, scale=scale).astype(np.float32))


def rand_adj(rng, n, density=0.1, self_loops=True):
    a = (rng.random((n, n)) < density).astype(np.float32)
    a = np.maximum(a, a.T)
    if self_loops:
        np.fill_diagonal(a, 1.0)
    return jnp.asarray(a)


def allclose(a, b, tol=3e-4):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# pick_block
# ---------------------------------------------------------------------------

@given(dim=st.integers(1, 4096), preferred=st.sampled_from([8, 64, 128]))
def test_pick_block_divides(dim, preferred):
    b = pick_block(dim, preferred)
    assert dim % b == 0
    assert 1 <= b <= preferred


def test_pick_block_prefers_largest():
    assert pick_block(320, 128) == 64
    assert pick_block(1536, 128) == 128
    assert pick_block(512, 128) == 128
    assert pick_block(7, 64) == 1


# ---------------------------------------------------------------------------
# matmul family
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=DIMS, k=DIMS, n=SMALL_DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    allclose(matmul(x, y), ref.matmul(x, y), tol=1e-3)


@settings(max_examples=15, deadline=None)
@given(m=DIMS, k=DIMS, act=st.sampled_from(["none", "relu", "sigmoid"]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_bias_act_matches_ref(m, k, act, seed):
    rng = np.random.default_rng(seed)
    n = 64
    x, y, b = rand(rng, m, k), rand(rng, k, n), rand(rng, 1, n)
    allclose(matmul_bias_act(x, y, b, act),
             ref.matmul_bias_act(x, y, b, act), tol=1e-3)


def test_matmul_bias_act_rejects_unknown_act():
    x = jnp.zeros((8, 8))
    with pytest.raises(ValueError):
        matmul_bias_act(x, x, jnp.zeros((1, 8)), act="gelu")


def test_matmul_identity():
    rng = np.random.default_rng(0)
    x = rand(rng, 64, 64)
    allclose(matmul(x, jnp.eye(64)), x)


def test_matmul_zero_operand():
    rng = np.random.default_rng(1)
    x = rand(rng, 64, 128)
    out = matmul(x, jnp.zeros((128, 8)))
    assert np.allclose(np.asarray(out), 0.0)


# ---------------------------------------------------------------------------
# mean aggregation
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([64, 128, 320]), f=st.sampled_from([8, 64, 512]),
       density=st.floats(0.01, 0.5), seed=st.integers(0, 2**31 - 1))
def test_mean_agg_matches_ref(n, f, density, seed):
    rng = np.random.default_rng(seed)
    adj = rand_adj(rng, n, density)
    x = rand(rng, n, f)
    inv_deg = ref.inv_degree(adj)
    allclose(mean_agg(adj, x, inv_deg), ref.mean_agg(adj, x, inv_deg),
             tol=1e-3)


def test_mean_agg_isolated_rows_zero():
    """Rows with zero degree (padding) must aggregate to exactly 0."""
    rng = np.random.default_rng(3)
    n = 64
    adj = np.zeros((n, n), dtype=np.float32)
    adj[: n // 2, : n // 2] = np.asarray(rand_adj(rng, n // 2))
    adj = jnp.asarray(adj)
    x = rand(rng, n, 64)
    out = np.asarray(mean_agg(adj, x, ref.inv_degree(adj)))
    assert np.all(out[n // 2:] == 0.0)


def test_mean_agg_uniform_graph_is_mean():
    """On a complete graph with self-loops the aggregate is the column
    mean of x, for every vertex."""
    rng = np.random.default_rng(4)
    n = 64
    adj = jnp.ones((n, n), dtype=jnp.float32)
    x = rand(rng, n, 8)
    out = mean_agg(adj, x, ref.inv_degree(adj))
    expect = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True), (n, 8))
    allclose(out, expect)


# ---------------------------------------------------------------------------
# GAT attention
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([64, 128, 320]), seed=st.integers(0, 2**31 - 1))
def test_attn_scores_matches_ref(n, seed):
    rng = np.random.default_rng(seed)
    sl, sr = rand(rng, n, 1), rand(rng, n, 1)
    allclose(attn_scores(sl, sr), ref.attn_scores(sl, sr))


@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([64, 128, 320]), density=st.floats(0.02, 0.6),
       seed=st.integers(0, 2**31 - 1))
def test_masked_softmax_matches_ref(n, density, seed):
    rng = np.random.default_rng(seed)
    adj = rand_adj(rng, n, density)
    scores = rand(rng, n, n, scale=3.0)
    allclose(masked_softmax(scores, adj), ref.masked_softmax(scores, adj))


@settings(max_examples=10, deadline=None)
@given(n=st.sampled_from([64, 128]), seed=st.integers(0, 2**31 - 1))
def test_masked_softmax_rows_sum_to_one(n, seed):
    rng = np.random.default_rng(seed)
    adj = rand_adj(rng, n, 0.2)
    out = np.asarray(masked_softmax(rand(rng, n, n), adj))
    np.testing.assert_allclose(out.sum(axis=1), np.ones(n), rtol=1e-4)


def test_masked_softmax_empty_rows_are_zero():
    """All-masked (padding) rows must produce zeros, not NaN."""
    rng = np.random.default_rng(9)
    n = 64
    adj = np.zeros((n, n), dtype=np.float32)
    adj[:32, :32] = 1.0
    out = np.asarray(masked_softmax(rand(rng, n, n), jnp.asarray(adj)))
    assert np.all(np.isfinite(out))
    assert np.all(out[32:] == 0.0)


def test_masked_softmax_respects_mask():
    rng = np.random.default_rng(10)
    n = 64
    adj = rand_adj(rng, n, 0.15)
    out = np.asarray(masked_softmax(rand(rng, n, n), adj))
    assert np.all(out[np.asarray(adj) == 0.0] == 0.0)
