"""L2 model forwards (kernel-composed) vs layer oracles, shape contracts."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(42)


def make_graph(n_real=200, feat_pad=512):
    n = model.N_MAX
    adj = np.zeros((n, n), dtype=np.float32)
    block = (RNG.random((n_real, n_real)) < 0.05).astype(np.float32)
    block = np.maximum(block, block.T)
    adj[:n_real, :n_real] = block
    for i in range(n_real):
        adj[i, i] = 1.0
    adj = jnp.asarray(adj)
    x = np.zeros((n, feat_pad), dtype=np.float32)
    x[:n_real] = RNG.normal(size=(n_real, feat_pad)).astype(np.float32)
    return jnp.asarray(x), adj


def params_for(m, feat_pad):
    return [jnp.asarray(RNG.normal(size=s, scale=0.1).astype(np.float32))
            for _, s in model.param_specs(m, feat_pad)]


def run_forward(m, x, adj, params):
    a_norm = ref.sym_norm_adj(adj)
    inv_deg = ref.inv_degree(adj)
    env = {"x": x, "a_norm": a_norm, "adj": adj, "inv_deg": inv_deg}
    args = [env[k] for k in model.MODEL_INPUTS[m]]
    return model.FORWARDS[m](*args, *params)


@pytest.mark.parametrize("m", model.MODELS)
def test_forward_shape(m):
    x, adj = make_graph()
    out = run_forward(m, x, adj, params_for(m, 512))
    assert out.shape == (model.N_MAX, model.C_PAD)
    assert np.all(np.isfinite(np.asarray(out)))


@pytest.mark.parametrize("m", model.MODELS)
def test_forward_matches_oracle(m):
    x, adj = make_graph()
    params = params_for(m, 512)
    out = run_forward(m, x, adj, params)
    a_norm = ref.sym_norm_adj(adj)
    inv_deg = ref.inv_degree(adj)
    if m == "gcn":
        expect = ref.gcn_forward(a_norm, x, *params)
    elif m == "sgc":
        expect = ref.sgc_forward(a_norm, x, *params)
    elif m == "sage":
        expect = ref.sage_forward(adj, inv_deg, x, *params)
    else:
        w0, al0, ar0, b0, w1, al1, ar1, b1 = params
        expect = ref.gat_forward(adj, x, w0, al0[:, 0], ar0[:, 0], b0,
                                 w1, al1[:, 0], ar1[:, 0], b1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("m", model.MODELS)
def test_padding_vertices_isolated(m):
    """Padded (masked-out) vertices must not influence real logits."""
    x, adj = make_graph(n_real=100)
    params = params_for(m, 512)
    base = np.asarray(run_forward(m, x, adj, params))[:100]
    # Corrupt the padded rows' features; logits of real rows unchanged.
    x2 = np.asarray(x).copy()
    x2[100:] = 1e3
    out2 = np.asarray(run_forward(m, jnp.asarray(x2), adj, params))[:100]
    np.testing.assert_allclose(base, out2, rtol=1e-4, atol=1e-4)


def test_dataset_specs_consistent():
    for name, spec in model.DATASETS.items():
        assert spec["feat_pad"] % 128 == 0 or spec["feat_pad"] % 64 == 0
        assert spec["feat"] <= spec["feat_pad"]
        assert spec["classes"] <= model.C_PAD


def test_param_specs_unknown_model():
    with pytest.raises(ValueError):
        model.param_specs("transformer", 512)
