"""Artifact-build-time GNN pre-training.

The paper (§6.1) deploys *pre-trained* GNNs (GCN/GAT/GraphSAGE/SGC) on
every edge server, each at 60–80% node-classification accuracy.  This
module reproduces that: for each (model, dataset) pair it trains the
2-layer model on padded 320-vertex subgraphs sampled from the synthetic
dataset, early-stopping inside the paper's accuracy band, and returns
the parameter list in the exact order the AOT executable binds them.

Training differentiates through the pure-jnp oracles in ``kernels.ref``
(same math as the Pallas kernels — equivalence is enforced by
``python/tests/test_kernels.py``), because reverse-mode AD through
interpret-mode Pallas is both slow and unnecessary here.
"""

import numpy as np
import jax
import jax.numpy as jnp

from . import data as data_mod
from . import model as model_mod
from .kernels import ref

ACC_LO, ACC_HI = 0.60, 0.80
MAX_STEPS = 400
EVAL_EVERY = 5
LR = 0.01


def sample_subgraph(d, adj, size, rng):
    """BFS ball around a random seed, induced subgraph of ``size``."""
    n = d["n"]
    seen, order = set(), []
    frontier = [int(rng.integers(0, n))]
    while len(order) < size:
        if not frontier:
            frontier = [int(rng.integers(0, n))]
        nxt = []
        for u in frontier:
            if u in seen:
                continue
            seen.add(u)
            order.append(u)
            if len(order) >= size:
                break
            nxt.extend(adj[u])
        frontier = nxt
    order = order[:size]
    index = {u: k for k, u in enumerate(order)}
    a = np.zeros((model_mod.N_MAX, model_mod.N_MAX), dtype=np.float32)
    for u in order:
        for v in adj[u]:
            if v in index:
                a[index[u], index[v]] = 1.0
    for k in range(size):
        a[k, k] = 1.0  # self loops
    return order, a


def build_batch(d, adj, feat_pad, rng, size=300):
    order, a = sample_subgraph(d, adj, size, rng)
    x = np.zeros((model_mod.N_MAX, feat_pad), dtype=np.float32)
    x[:len(order)] = data_mod.dense_features(d, feat_pad, rows=order)
    y = np.full(model_mod.N_MAX, -1, dtype=np.int32)
    y[:len(order)] = d["labels"][order]
    return (jnp.asarray(x), jnp.asarray(a), jnp.asarray(y))


def ref_forward(model, x, a, params):
    """Dispatch to the oracle forward with (adj-with-self-loops) ``a``."""
    a_norm = ref.sym_norm_adj(a)
    inv_deg = ref.inv_degree(a)
    if model == "gcn":
        return ref.gcn_forward(a_norm, x, *params)
    if model == "sgc":
        return ref.sgc_forward(a_norm, x, *params)
    if model == "sage":
        return ref.sage_forward(a, inv_deg, x, *params)
    if model == "gat":
        w0, al0, ar0, b0, w1, al1, ar1, b1 = params
        return ref.gat_forward(a, x, w0, al0[:, 0], ar0[:, 0], b0,
                               w1, al1[:, 0], ar1[:, 0], b1)
    raise ValueError(model)


def init_params(model, feat_pad, key):
    params = []
    for name, shape in model_mod.param_specs(model, feat_pad):
        key, sub = jax.random.split(key)
        if name.startswith("b"):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0]
            scale = jnp.sqrt(2.0 / fan_in)
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def pretrain(model, dataset_name, d, seed=7, log=print):
    """Train; returns (params, accuracy).  Early-stops in [0.60, 0.80]."""
    spec = model_mod.DATASETS[dataset_name]
    feat_pad = spec["feat_pad"]
    adj = data_mod.adjacency_lists(d)
    rng = np.random.default_rng(seed)
    train_b = [build_batch(d, adj, feat_pad, rng) for _ in range(3)]
    val_b = build_batch(d, adj, feat_pad, rng)

    def loss_fn(params, x, a, y):
        logits = ref_forward(model, x, a, params)
        mask = (y >= 0).astype(jnp.float32)
        yc = jnp.clip(y, 0)
        logp = jax.nn.log_softmax(logits[:, :spec["classes"]], axis=-1)
        nll = -jnp.take_along_axis(logp, yc[:, None], axis=-1)[:, 0]
        return jnp.sum(nll * mask) / jnp.sum(mask)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))

    @jax.jit
    def accuracy(params, x, a, y):
        logits = ref_forward(model, x, a, params)
        pred = jnp.argmax(logits[:, :spec["classes"]], axis=-1)
        mask = y >= 0
        return jnp.sum((pred == y) & mask) / jnp.sum(mask)

    params = init_params(model, feat_pad, jax.random.PRNGKey(seed))
    m_state = [jnp.zeros_like(p) for p in params]
    v_state = [jnp.zeros_like(p) for p in params]
    acc = 0.0
    for step in range(1, MAX_STEPS + 1):
        x, a, y = train_b[step % len(train_b)]
        _, grads = grad_fn(params, x, a, y)
        t = float(step)
        for i, g in enumerate(grads):
            m_state[i] = 0.9 * m_state[i] + 0.1 * g
            v_state[i] = 0.999 * v_state[i] + 0.001 * g * g
            mh = m_state[i] / (1 - 0.9 ** t)
            vh = v_state[i] / (1 - 0.999 ** t)
            params[i] = params[i] - LR * mh / (jnp.sqrt(vh) + 1e-8)
        if step % EVAL_EVERY == 0:
            acc = float(accuracy(params, *val_b))
            if acc >= ACC_LO:
                break  # stop as soon as we enter the paper's band
    log(f"    pretrain {model}/{dataset_name}: acc={acc:.3f} steps<= {step}")
    return params, acc
