"""Layer-2 JAX GNN model definitions (compile-time only).

Each forward is a pure function over *padded, fixed-shape* inputs so it
can be AOT-lowered once per (model, dataset) pair and executed from the
Rust runtime with no Python anywhere near the request path.

Common input signature (all models):

    forward(x, a_norm, adj, inv_deg, *params) -> logits [N_MAX, C_PAD]

  x        [N_MAX, F_pad]   row-normalized bag-of-words features, zero
                            rows for padding vertices
  a_norm   [N_MAX, N_MAX]   D^-1/2 (A+I) D^-1/2 (zero rows/cols padding)
  adj      [N_MAX, N_MAX]   0/1 adjacency with self-loops
  inv_deg  [N_MAX, 1]       1/deg over `adj` (0 for padded rows)

All four inputs are produced by the Rust serving layer for every batch;
unused ones per model are still bound (uniform runtime plumbing) but
dropped by XLA's DCE after lowering, so they cost nothing at run time —
except they'd be dead *arguments*; to keep executables minimal each
model variant lowers only the inputs it reads (see `MODEL_INPUTS`).

Hidden width and class padding follow the paper's setup (§6.1: 64
neurons per layer; CiteSeer/Cora/PubMed have 6/7/3 classes, padded to 8
lanes for tiling).
"""

import jax.numpy as jnp

from .kernels import (
    attn_scores,
    masked_softmax,
    matmul,
    matmul_bias_act,
    mean_agg,
)

#: Padded vertex count: max users per scenario is 300 (Table 2), +halo
#: margin, rounded to 64-lane tiles.
N_MAX = 320
#: Hidden width (paper §6.1: every layer 64 neurons).
HIDDEN = 64
#: Class logits padded to one 8-lane tile.
C_PAD = 8

#: Dataset specs: (real feature dim capped at 1500 per §6.1, padded
#: feature dim for tiling, real class count).
DATASETS = {
    "citeseer": {"feat": 1500, "feat_pad": 1536, "classes": 6},
    "cora": {"feat": 1433, "feat_pad": 1536, "classes": 7},
    "pubmed": {"feat": 500, "feat_pad": 512, "classes": 3},
}

#: Which of (x, a_norm, adj, inv_deg) each model forward consumes, in
#: signature order.  The AOT pipeline and the Rust runtime both read
#: this table (via the manifest) so the binding stays in one place.
MODEL_INPUTS = {
    "gcn": ("x", "a_norm"),
    "sgc": ("x", "a_norm"),
    "sage": ("x", "adj", "inv_deg"),
    "gat": ("x", "adj"),
}

#: Parameter name/shape templates per model (F = padded feature dim).
def param_specs(model: str, feat_pad: int):
    h, c = HIDDEN, C_PAD
    if model == "gcn":
        return [("w0", (feat_pad, h)), ("b0", (1, h)),
                ("w1", (h, c)), ("b1", (1, c))]
    if model == "sgc":
        return [("w", (feat_pad, c)), ("b", (1, c))]
    if model == "sage":
        return [("ws0", (feat_pad, h)), ("wn0", (feat_pad, h)), ("b0", (1, h)),
                ("ws1", (h, c)), ("wn1", (h, c)), ("b1", (1, c))]
    if model == "gat":
        return [("w0", (feat_pad, h)), ("al0", (h, 1)), ("ar0", (h, 1)),
                ("b0", (1, h)),
                ("w1", (h, c)), ("al1", (c, 1)), ("ar1", (c, 1)),
                ("b1", (1, c))]
    raise ValueError(f"unknown model {model!r}")


# ---------------------------------------------------------------------------
# Forwards (kernel-composed)
# ---------------------------------------------------------------------------

def gcn_forward(x, a_norm, w0, b0, w1, b1):
    """Two-layer GCN (paper Eq. 2).  The per-layer hot path is the
    fused aggregate kernel: P = X@W via `matmul`, then act(A_hat@P + b)
    via `matmul_bias_act` — bias/ReLU fused into the last VMEM tile."""
    h = matmul_bias_act(a_norm, matmul(x, w0), b0, act="relu")
    return matmul_bias_act(a_norm, matmul(h, w1), b1, act="none")


def sgc_forward(x, a_norm, w, b):
    """SGC: A_hat^2 X W + b.  Propagation order A@(A@X) keeps every
    contraction at K = N_MAX instead of touching F twice."""
    p = matmul(a_norm, matmul(a_norm, x))
    return matmul_bias_act(p, w, b, act="none")


def sage_forward(x, adj, inv_deg, ws0, wn0, b0, ws1, wn1, b1):
    """Two GraphSAGE-mean layers with the degree-fused mean_agg kernel."""
    neigh = mean_agg(adj, x, inv_deg)
    h = _sage_combine(x, neigh, ws0, wn0, b0, act="relu")
    neigh2 = mean_agg(adj, h, inv_deg)
    return _sage_combine(h, neigh2, ws1, wn1, b1, act="none")


def _sage_combine(x, neigh, w_self, w_neigh, b, act):
    v = matmul(x, w_self) + matmul_bias_act(neigh, w_neigh, b, act="none")
    return jnp.maximum(v, 0.0) if act == "relu" else v


def gat_forward(x, adj, w0, al0, ar0, b0, w1, al1, ar1, b1):
    """Two single-head GATv1 layers; attention scores, masked softmax
    and the attention-weighted aggregation all run as Pallas kernels."""
    h = _gat_layer(x, adj, w0, al0, ar0, b0, act="relu")
    return _gat_layer(h, adj, w1, al1, ar1, b1, act="none")


def _gat_layer(x, adj, w, a_l, a_r, b, act):
    h = matmul(x, w)
    sl = matmul(h, a_l)           # [N, 1]
    sr = matmul(h, a_r)           # [N, 1]
    att = masked_softmax(attn_scores(sl, sr), adj)
    return matmul_bias_act(att, h, b, act=act)


FORWARDS = {
    "gcn": gcn_forward,
    "sgc": sgc_forward,
    "sage": sage_forward,
    "gat": gat_forward,
}

MODELS = tuple(FORWARDS)
