"""Layer-1 Pallas kernels for GraphEdge GNN inference.

Every kernel here is authored for a TPU-shaped memory hierarchy (VMEM
tiles via BlockSpec, MXU-friendly dense contractions) but executed in
``interpret=True`` mode so the lowered HLO runs on the CPU PJRT plugin
(real-TPU lowering emits Mosaic custom-calls the CPU client cannot run).

Kernels:
  - :func:`matmul`            blocked dense matmul with k-loop accumulation
  - :func:`matmul_bias_act`   matmul fused with bias + activation epilogue
  - :func:`mean_agg`          neighborhood mean aggregation (SAGE)
  - :func:`attn_scores`       pairwise additive-attention logits (GAT)
  - :func:`masked_softmax`    row softmax over adjacency-masked logits (GAT)

The pure-jnp oracle for each kernel lives in :mod:`ref` and is the
correctness ground truth exercised by ``python/tests``.
"""

from .matmul import matmul, matmul_bias_act, pick_block
from .sage import mean_agg
from .gat import attn_scores, masked_softmax

__all__ = [
    "matmul",
    "matmul_bias_act",
    "mean_agg",
    "attn_scores",
    "masked_softmax",
    "pick_block",
]
