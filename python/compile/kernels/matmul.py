"""Blocked dense matmul Pallas kernels.

These are the workhorse contractions of GraphEdge's GNN layers: the
feature transform ``X @ W`` (K up to 1536) and the neighborhood
aggregation ``A_hat @ P`` (K = N_max = 320).

TPU adaptation notes (see DESIGN.md §Hardware-Adaptation): the grid is
(row-tile i, col-tile j, contraction-tile k).  Each (i, j) output tile
lives in VMEM for the whole k loop (Pallas revisits the same out block
while only the k coordinate advances), so HBM traffic is one read of
each X/W tile and a single write of the output tile — the schedule a
CUDA kernel would express with a threadblock loop over shared-memory
staging buffers.  ``jnp.dot(..., preferred_element_type=f32)`` targets
the MXU with an f32 accumulator.  On CPU we run ``interpret=True``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default tile sizes (§Perf-tuned).  A full 320-row block, 512-lane
#: output tiles and 512-deep contraction blocks keep every tile pair
#: under ~1.7 MB — comfortably double-bufferable in a 16 MB VMEM — while
#: cutting the grid from hundreds of steps to a handful (the original
#: 64/64/128 tiling spent >90% of CPU-interpret time on grid overhead;
#: see EXPERIMENTS.md §Perf: 62 ms → 5 ms per GCN forward).
BM, BN, BK = 320, 512, 512

#: Tile candidates tried by :func:`pick_block`, largest first.  Includes
#: the 5·2^k family because N_MAX = 320.
_CANDIDATES = (512, 384, 320, 256, 192, 160, 128, 96, 64, 48, 32, 16, 8, 4, 2)


def pick_block(dim: int, preferred: int) -> int:
    """Largest candidate tile <= ``preferred`` that divides ``dim``.

    L2 pads every tensor so that a reasonable tile always exists; this
    helper keeps BlockSpecs exact (no ragged masking needed inside the
    kernel body).
    """
    for c in _CANDIDATES:
        if c <= preferred and dim % c == 0:
            return c
    return 1


def _mm_kernel(x_ref, y_ref, o_ref):
    """out[i, j] += x[i, k] @ y[k, j], accumulated over the k grid axis."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _mm_epilogue_kernel(x_ref, y_ref, b_ref, o_ref, *, act: str, nsteps: int):
    """Matmul with a fused bias-add + activation applied on the last
    contraction step, so the epilogue happens while the output tile is
    still resident in VMEM."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _epilogue():
        v = o_ref[...] + b_ref[...]
        if act == "relu":
            v = jnp.maximum(v, 0.0)
        elif act == "sigmoid":
            v = jax.nn.sigmoid(v)
        elif act == "none":
            pass
        else:  # pragma: no cover - guarded by matmul_bias_act
            raise ValueError(f"unknown activation {act!r}")
        o_ref[...] = v


def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """``x @ y`` via the blocked Pallas kernel.

    Shapes must tile cleanly (guaranteed by L2's padding); result f32.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    bm, bn, bk = pick_block(m, BM), pick_block(n, BN), pick_block(k, BK)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)


def matmul_bias_act(
    x: jax.Array, y: jax.Array, b: jax.Array, act: str = "none"
) -> jax.Array:
    """``act(x @ y + b)`` with the bias/activation fused into the last
    contraction step of the blocked matmul.

    ``b`` has shape ``(1, n)`` (kept 2-D so the BlockSpec stays rank-
    consistent with the output tile).  ``act`` in {"none","relu","sigmoid"}.
    """
    if act not in ("none", "relu", "sigmoid"):
        raise ValueError(f"unknown activation {act!r}")
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {x.shape} @ {y.shape}"
    assert b.shape == (1, n), f"bias must be (1, {n}), got {b.shape}"
    bm, bn, bk = pick_block(m, BM), pick_block(n, BN), pick_block(k, BK)
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(
        _mm_epilogue_kernel, act=act, nsteps=k // bk
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y, b)
