"""GAT attention Pallas kernels.

Additive (GATv1) attention over a dense padded adjacency:

  ``e[i, j] = LeakyReLU(s_l[i] + s_r[j])``          (:func:`attn_scores`)
  ``att[i, :] = softmax over {j : adj[i, j] = 1}``  (:func:`masked_softmax`)

where ``s_l = (X W) @ a_l`` and ``s_r = (X W) @ a_r`` are computed by L2
with the matmul kernel.  ``attn_scores`` tiles the [N, N] score matrix;
``masked_softmax`` processes whole rows per tile (N_max = 320 columns
fit VMEM comfortably) so max/sum reductions stay on-chip.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block, BM

#: LeakyReLU negative slope used by GAT.
NEG_SLOPE = 0.2
#: Additive mask value for non-edges (large negative, exp() underflows).
MASK_VALUE = -1e30


def _attn_scores_kernel(sl_ref, sr_ref, o_ref):
    # sl tile: (bm, 1) column of left scores; sr tile: (1, bn) row of
    # right scores (pre-transposed by the caller's BlockSpec on a
    # [1, N] input).  Outer broadcast add, then LeakyReLU.
    e = sl_ref[...] + sr_ref[...]
    o_ref[...] = jnp.where(e >= 0.0, e, NEG_SLOPE * e)


def attn_scores(sl: jax.Array, sr: jax.Array) -> jax.Array:
    """``LeakyReLU(sl + sr^T)`` for column vectors sl, sr of shape [N, 1]."""
    n = sl.shape[0]
    assert sl.shape == (n, 1) and sr.shape == (n, 1), (sl.shape, sr.shape)
    srt = sr.reshape(1, n)
    bm = pick_block(n, BM)
    bn = pick_block(n, BM)
    grid = (n // bm, n // bn)
    return pl.pallas_call(
        _attn_scores_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(sl, srt)


def _masked_softmax_kernel(s_ref, m_ref, o_ref):
    s = s_ref[...]
    mask = m_ref[...] > 0.0
    s = jnp.where(mask, s, MASK_VALUE)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s) * mask.astype(jnp.float32)
    # Padded rows have no edges at all: denominator epsilon keeps them 0.
    o_ref[...] = e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-9)


def masked_softmax(scores: jax.Array, adj: jax.Array) -> jax.Array:
    """Row-wise softmax of ``scores`` restricted to ``adj != 0`` entries.

    Rows with no edges (padding) come out all-zero rather than NaN.
    Each grid step owns ``bm`` complete rows so the reduction never
    crosses tiles.
    """
    n, n2 = scores.shape
    assert n == n2 and adj.shape == (n, n)
    bm = pick_block(n, BM)
    grid = (n // bm,)
    return pl.pallas_call(
        _masked_softmax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=True,
    )(scores, adj)
