"""Pure-jnp oracles for every Pallas kernel and every composed GNN layer.

This module is the correctness ground truth of the whole stack:

  * ``python/tests/test_kernels.py`` sweeps the Pallas kernels against
    these oracles with hypothesis-generated shapes/values.
  * ``python/tests/test_model.py`` checks the L2 model forwards
    (kernel-composed) against the layer oracles here.
  * GNN pre-training (``train_gnn.py``) trains *through* these oracles
    (differentiable plain-jnp), and serving runs the Pallas version —
    the tests above are what make that substitution sound.
  * The **Rust native kernels** (``rust/src/runtime/native/kernels.rs``,
    the default serving backend) are a third consumer: they are pinned
    to this math at **1e-4 absolute** by committed golden vectors
    (``scripts/gen_kernel_fixtures.py`` — a numpy-float64 mirror of the
    oracles below — replayed by ``rust/tests/kernel_parity.rs``).
    Any semantic change here must regenerate those fixtures.

No pallas imports allowed in this file.
"""

import jax
import jax.numpy as jnp

NEG_SLOPE = 0.2


# ---------------------------------------------------------------------------
# Kernel-level oracles
# ---------------------------------------------------------------------------

def matmul(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def matmul_bias_act(x, y, b, act="none"):
    v = jnp.dot(x, y, preferred_element_type=jnp.float32) + b
    if act == "relu":
        v = jnp.maximum(v, 0.0)
    elif act == "sigmoid":
        v = jax.nn.sigmoid(v)
    elif act != "none":
        raise ValueError(act)
    return v


def mean_agg(adj, x, inv_deg):
    return jnp.dot(adj, x, preferred_element_type=jnp.float32) * inv_deg


def attn_scores(sl, sr):
    e = sl + sr.reshape(1, -1)
    return jnp.where(e >= 0.0, e, NEG_SLOPE * e)


def masked_softmax(scores, adj):
    mask = adj > 0.0
    s = jnp.where(mask, scores, -1e30)
    s = s - jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s) * mask.astype(jnp.float32)
    return e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-9)


# ---------------------------------------------------------------------------
# Layer-level oracles (what the L2 model composes out of kernels)
# ---------------------------------------------------------------------------

def gcn_layer(a_norm, x, w, b, act="relu"):
    """One GCN layer: ``act(A_hat @ X @ W + b)`` (Kipf & Welling, Eq. 1)."""
    return matmul_bias_act(a_norm, jnp.dot(x, w), b, act)


def gcn_forward(a_norm, x, w0, b0, w1, b1):
    """Two-layer GCN, paper Eq. (2): softmax omitted (argmax-invariant)."""
    h = gcn_layer(a_norm, x, w0, b0, act="relu")
    return gcn_layer(a_norm, h, w1, b1, act="none")


def sage_layer(adj, inv_deg, x, w_self, w_neigh, b, act="relu"):
    """GraphSAGE-mean layer: ``act(X W_self + mean_N(X) W_neigh + b)``."""
    neigh = mean_agg(adj, x, inv_deg)
    v = jnp.dot(x, w_self) + jnp.dot(neigh, w_neigh) + b
    if act == "relu":
        v = jnp.maximum(v, 0.0)
    return v


def sage_forward(adj, inv_deg, x, ws0, wn0, b0, ws1, wn1, b1):
    h = sage_layer(adj, inv_deg, x, ws0, wn0, b0, act="relu")
    return sage_layer(adj, inv_deg, h, ws1, wn1, b1, act="none")


def gat_layer(adj, x, w, a_l, a_r, b, act="relu"):
    """Single-head GATv1 layer over a dense masked adjacency."""
    h = jnp.dot(x, w)
    sl = jnp.dot(h, a_l).reshape(-1, 1)
    sr = jnp.dot(h, a_r).reshape(-1, 1)
    att = masked_softmax(attn_scores(sl, sr), adj)
    v = jnp.dot(att, h) + b
    if act == "relu":
        v = jnp.maximum(v, 0.0)
    return v


def gat_forward(adj, x, w0, al0, ar0, b0, w1, al1, ar1, b1):
    h = gat_layer(adj, x, w0, al0, ar0, b0, act="relu")
    return gat_layer(adj, h, w1, al1, ar1, b1, act="none")


def sgc_forward(a_norm, x, w, b, k=2):
    """SGC (Wu et al., 2019): ``A_hat^K X W + b`` — no nonlinearity."""
    p = x
    for _ in range(k):
        p = jnp.dot(a_norm, p)
    return jnp.dot(p, w) + b


# ---------------------------------------------------------------------------
# Graph-operator helpers shared by oracle users
# ---------------------------------------------------------------------------

def sym_norm_adj(adj_with_self_loops):
    """``D^-1/2 (A + I) D^-1/2`` with 0 rows for padded vertices."""
    deg = jnp.sum(adj_with_self_loops, axis=1)
    inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)
    return adj_with_self_loops * inv_sqrt[:, None] * inv_sqrt[None, :]


def inv_degree(adj):
    deg = jnp.sum(adj, axis=1, keepdims=True)
    return jnp.where(deg > 0, 1.0 / jnp.maximum(deg, 1e-12), 0.0)
