"""GraphSAGE mean-aggregation Pallas kernel.

``mean_agg(adj, x, inv_deg) = diag(inv_deg) @ (adj @ x)`` — the mean of
every vertex's neighborhood features (self-loops included by L2), which
is the aggregator of GraphSAGE-mean (Hamilton et al., 2017).

The degree normalization is fused into the final contraction step so
the scaled tile is produced while still VMEM-resident, instead of a
second full pass over the [N, F] aggregate.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import pick_block, BM, BN, BK


def _mean_agg_kernel(a_ref, x_ref, d_ref, o_ref, *, nsteps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], x_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _scale():
        # d_ref is the (bm, 1) column of reciprocal degrees for this
        # row tile; broadcast-multiply the finished aggregate.
        o_ref[...] = o_ref[...] * d_ref[...]


def mean_agg(adj: jax.Array, x: jax.Array, inv_deg: jax.Array) -> jax.Array:
    """Neighborhood mean: ``(adj @ x) * inv_deg``.

    Args:
      adj: [N, N] 0/1 adjacency (self-loops per the caller's convention).
      x: [N, F] vertex features.
      inv_deg: [N, 1] reciprocal row degree (0 for isolated/padded rows).
    """
    n, n2 = adj.shape
    nx, f = x.shape
    assert n == n2 == nx, f"shape mismatch adj={adj.shape} x={x.shape}"
    assert inv_deg.shape == (n, 1), f"inv_deg must be ({n},1)"
    bm, bn, bk = pick_block(n, BM), pick_block(f, BN), pick_block(n, BK)
    grid = (n // bm, f // bn, n // bk)
    kernel = functools.partial(_mean_agg_kernel, nsteps=n // bk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, f), jnp.float32),
        interpret=True,
    )(adj, x, inv_deg)
