"""GTA — the GraphEdge Tensor Archive format (writer side).

A deliberately tiny, dependency-free binary container for named f32/i32
tensors, used to ship pre-trained GNN weights and DRL initial parameters
from the Python compile path to the Rust runtime (reader:
``rust/src/tensor/gta.rs``).

Layout (little-endian):

    magic  b"GTA1"
    u32    tensor count
    per tensor:
        u16   name length, then UTF-8 name bytes
        u8    dtype (0 = f32, 1 = i32)
        u8    ndim
        u32×ndim  dims
        raw   data (row-major, packed)
"""

import struct

import numpy as np

MAGIC = b"GTA1"
DTYPE_F32 = 0
DTYPE_I32 = 1


def write_gta(path, tensors):
    """Write ``tensors`` (list of (name, np.ndarray)) to ``path``.

    Arrays are converted to f32 unless integer-typed (then i32).
    Order is preserved — the Rust runtime binds executable parameter
    inputs positionally from the archive order.
    """
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.asarray(arr)
            if np.issubdtype(arr.dtype, np.integer):
                arr = arr.astype(np.int32)
                dtype = DTYPE_I32
            else:
                arr = arr.astype(np.float32)
                dtype = DTYPE_F32
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", dtype, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes(order="C"))


def read_gta(path):
    """Reader (python side, used only by tests for round-trip checks)."""
    out = []
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad GTA magic"
        (count,) = struct.unpack("<I", f.read(4))
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dtype, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            np_dtype = np.float32 if dtype == DTYPE_F32 else np.int32
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype=np_dtype).reshape(dims)
            out.append((name, data))
    return out
