"""AOT pipeline: lower every L1/L2 computation to HLO text artifacts.

This is the single entry point of the Python compile path
(``make artifacts`` → ``python -m compile.aot --out ../artifacts``).
It produces everything the Rust runtime needs and nothing else ever
imports Python again:

  artifacts/
    data/{citeseer,cora,pubmed}.geb        synthetic datasets
    models/<model>_<dataset>.hlo.txt       12 GNN forward executables
    models/<model>_<dataset>.weights.gta   pre-trained parameters
    drl/actor_fwd.hlo.txt                  MADDPG rollout forward
    drl/maddpg_train.hlo.txt               full M-agent MADDPG update
    drl/ppo_fwd.hlo.txt                    PTOM rollout forward
    drl/ppo_train.hlo.txt                  PTOM PPO update
    drl/drl_init.gta                       initial params + Adam state
    manifest.json                          shapes/order of all bindings

Interchange format is HLO *text*, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.
"""

import argparse
import hashlib
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import drl
from . import model as model_mod
from . import train_gnn
from .gta import write_gta

F32 = jnp.float32


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_to_file(fn, specs, path):
    text = to_hlo_text(jax.jit(fn).lower(*specs))
    with open(path, "w") as f:
        f.write(text)
    return text


# ---------------------------------------------------------------------------
# GNN executables
# ---------------------------------------------------------------------------

GRAPH_INPUT_SHAPES = {
    "x": None,  # [N_MAX, feat_pad] — filled per dataset
    "a_norm": (model_mod.N_MAX, model_mod.N_MAX),
    "adj": (model_mod.N_MAX, model_mod.N_MAX),
    "inv_deg": (model_mod.N_MAX, 1),
}


def gnn_entry(model, dataset, out_dir, weights, manifest):
    ds = model_mod.DATASETS[dataset]
    feat_pad = ds["feat_pad"]
    fwd = model_mod.FORWARDS[model]
    graph_inputs = model_mod.MODEL_INPUTS[model]
    pspecs = model_mod.param_specs(model, feat_pad)

    specs, inputs_meta = [], []
    for gi in graph_inputs:
        shape = (model_mod.N_MAX, feat_pad) if gi == "x" \
            else GRAPH_INPUT_SHAPES[gi]
        specs.append(spec(shape))
        inputs_meta.append({"name": gi, "shape": list(shape)})
    for name, shape in pspecs:
        specs.append(spec(shape))
        inputs_meta.append({"name": name, "shape": list(shape)})

    key = f"{model}_{dataset}"
    hlo_path = os.path.join(out_dir, "models", f"{key}.hlo.txt")
    wpath = os.path.join(out_dir, "models", f"{key}.weights.gta")

    def wrapped(*args):
        return (fwd(*args),)

    lower_to_file(wrapped, specs, hlo_path)
    write_gta(wpath, [(n, np.asarray(p)) for (n, _), p in
                      zip(pspecs, weights)])

    manifest["executables"][key] = {
        "path": f"models/{key}.hlo.txt",
        "weights": f"models/{key}.weights.gta",
        "graph_inputs": list(graph_inputs),
        "inputs": inputs_meta,
        "outputs": [{"name": "logits",
                     "shape": [model_mod.N_MAX, model_mod.C_PAD]}],
    }


# ---------------------------------------------------------------------------
# DRL executables
# ---------------------------------------------------------------------------

def drl_entries(out_dir, manifest, seed=11):
    M, OBS, ACT, ST, B = drl.M, drl.OBS, drl.ACT, drl.STATE, drl.BATCH
    Pa, Pc, Pp = drl.P_ACTOR, drl.P_CRITIC, drl.P_PPO
    dd = os.path.join(out_dir, "drl")

    def emit(name, fn, shapes, outs):
        lower_to_file(fn, [spec(s, dt) for s, dt in shapes],
                      os.path.join(dd, f"{name}.hlo.txt"))
        manifest["executables"][name] = {
            "path": f"drl/{name}.hlo.txt",
            "inputs": [{"name": n, "shape": list(s)}
                       for (s, _), n in zip(shapes, outs["in"])],
            "outputs": [{"name": n} for n in outs["out"]],
        }

    emit("actor_fwd", drl.actor_fwd,
         [((M, Pa), F32), ((M, OBS), F32)],
         {"in": ["actor", "obs"], "out": ["actions"]})

    train_shapes = [
        ((M, Pa), F32), ((M, Pc), F32), ((M, Pa), F32), ((M, Pc), F32),
        ((M, Pa), F32), ((M, Pa), F32), ((M, Pc), F32), ((M, Pc), F32),
        ((), F32),
        ((B, ST), F32), ((B, M, ACT), F32), ((B, M), F32), ((B, ST), F32),
        ((B, M), F32), ((B, M, OBS), F32), ((B, M, OBS), F32),
    ]
    emit("maddpg_train", drl.maddpg_train, train_shapes,
         {"in": ["actor", "critic", "t_actor", "t_critic",
                 "m_a", "v_a", "m_c", "v_c", "step",
                 "s", "a", "r", "s2", "done", "obs", "obs2"],
          "out": ["actor", "critic", "t_actor", "t_critic",
                  "m_a", "v_a", "m_c", "v_c", "step",
                  "critic_loss", "actor_loss"]})

    emit("ppo_fwd", drl.ppo_fwd,
         [((Pp,), F32), ((1, ST), F32)],
         {"in": ["ppo", "s"], "out": ["logits", "value"]})

    emit("ppo_train", drl.ppo_train,
         [((Pp,), F32), ((Pp,), F32), ((Pp,), F32), ((), F32),
          ((B, ST), F32), ((B, M), F32), ((B,), F32), ((B,), F32),
          ((B,), F32)],
         {"in": ["ppo", "m_p", "v_p", "step", "s", "act_onehot",
                 "old_logp", "adv", "ret"],
          "out": ["ppo", "m_p", "v_p", "step",
                  "policy_loss", "value_loss", "entropy"]})

    # Initial parameters + optimizer state.
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, 2 * M + 1)
    actor = np.stack([np.asarray(drl.init_mlp(keys[i], drl.ACTOR_SHAPES))
                      for i in range(M)])
    critic = np.stack([np.asarray(drl.init_mlp(keys[M + i], drl.CRITIC_SHAPES))
                       for i in range(M)])
    ppo = np.asarray(drl.init_mlp(keys[-1], drl.PPO_SHAPES))
    write_gta(os.path.join(dd, "drl_init.gta"), [
        ("actor", actor), ("critic", critic),
        ("t_actor", actor.copy()), ("t_critic", critic.copy()),
        ("m_a", np.zeros_like(actor)), ("v_a", np.zeros_like(actor)),
        ("m_c", np.zeros_like(critic)), ("v_c", np.zeros_like(critic)),
        ("step", np.zeros((), np.float32)),
        ("ppo", ppo),
        ("ppo_m", np.zeros_like(ppo)), ("ppo_v", np.zeros_like(ppo)),
        ("ppo_step", np.zeros((), np.float32)),
    ])


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def input_fingerprint():
    """Hash of every compile-path source file, stored in the manifest so
    `make artifacts` can skip rebuilds when nothing changed."""
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for fn in sorted(files):
            if fn.endswith(".py"):
                with open(os.path.join(root, fn), "rb") as f:
                    h.update(f.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--skip-pretrain", action="store_true",
                    help="use random GNN weights (fast dev builds)")
    args = ap.parse_args()
    out = args.out
    for sub in ("data", "models", "drl"):
        os.makedirs(os.path.join(out, sub), exist_ok=True)

    manifest = {
        "version": 1,
        "fingerprint": input_fingerprint(),
        "constants": {
            "n_max": model_mod.N_MAX, "hidden": model_mod.HIDDEN,
            "c_pad": model_mod.C_PAD,
            "m_agents": drl.M, "obs_dim": drl.OBS, "act_dim": drl.ACT,
            "state_dim": drl.STATE, "batch": drl.BATCH,
            "p_actor": drl.P_ACTOR, "p_critic": drl.P_CRITIC,
            "p_ppo": drl.P_PPO,
        },
        "datasets": {},
        "executables": {},
        "accuracy": {},
    }

    print("[aot] generating synthetic datasets ...")
    datasets = {}
    for name in data_mod.SPECS:
        d = data_mod.generate(name)
        path = os.path.join(out, "data", f"{name}.geb")
        data_mod.write_geb(path, d)
        datasets[name] = d
        ds = model_mod.DATASETS[name]
        manifest["datasets"][name] = {
            "path": f"data/{name}.geb", "n": d["n"], "e": d["e"],
            "feat": ds["feat"], "feat_pad": ds["feat_pad"],
            "classes": ds["classes"],
        }
        print(f"  {name}: |V|={d['n']} |E|={d['e']} F={d['f']} C={d['c']}")

    print("[aot] pre-training + lowering GNN executables ...")
    for dataset, d in datasets.items():
        for model in model_mod.MODELS:
            if args.skip_pretrain:
                params = train_gnn.init_params(
                    model, model_mod.DATASETS[dataset]["feat_pad"],
                    jax.random.PRNGKey(1))
                acc = 0.0
            else:
                params, acc = train_gnn.pretrain(model, dataset, d)
            manifest["accuracy"][f"{model}_{dataset}"] = round(acc, 4)
            gnn_entry(model, dataset, out, params, manifest)

    print("[aot] lowering DRL executables ...")
    drl_entries(out, manifest)

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"[aot] wrote {out}/manifest.json "
          f"({len(manifest['executables'])} executables)")


if __name__ == "__main__":
    main()
