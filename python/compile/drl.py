"""Layer-2 DRL compute graphs: MADDPG (DRLGO) and PPO (PTOM baseline).

The entire training math — forward passes, gradients, Adam, soft target
updates — is expressed here as *pure functions over flat parameter
vectors* and AOT-lowered to HLO.  The Rust L3 driver owns the replay
buffer, the MAMDP environment, and the parameter literals; every
training step is one PJRT execution of ``maddpg_train`` (all M agents
updated in a single vmapped call) or ``ppo_train``.

Flat-vector parameter convention: each network's parameters live in one
1-D f32 vector, unflattened inside JAX with static slices (free after
fusion).  This keeps the Rust-side literal plumbing to a handful of
tensors instead of ~70.

Architecture (paper §6.1): every network has three hidden layers of 64
neurons.  Hyper-parameters are baked into the lowering from Table 2:
actor/critic lr 3e-4, γ = 0.99, τ = 0.01, batch 256.
"""

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Dimensions (must match rust/src/drl/env.rs — checked via the manifest)
# ---------------------------------------------------------------------------

M = 4            #: number of edge servers / agents (2000m plane, 500m cells)
OBS = 21         #: per-agent observation dim incl. the three layout-
                 #: maintenance slots (see rust drl::env docs).  The
                 #: scenario-diversity VecEnv (rust scenario::/vec_env)
                 #: does NOT change this layout: batch rows are
                 #: per-agent (M fixed by the manifest), per-slot user
                 #: counts only alter episode lengths and the per-slot
                 #: normalizers, so these artifacts serve mixed
                 #: scenario sets unchanged.
ACT = 2          #: paper Eq. (22): two-dimensional agent action in [0,1]^2
HID = 64         #: hidden width (§6.1)
STATE = M * OBS  #: global state = concat of local observations (Eq. 19)
BATCH = 256      #: experience mini-batch (Table 2)

LR = 3e-4
GAMMA = 0.99
TAU = 0.01
PPO_CLIP = 0.2
PPO_VCOEF = 0.5
PPO_ENTCOEF = 0.01
PPO_ACTIONS = M  #: PTOM picks one of M servers per user

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def mlp_shapes(in_dim, out_dim):
    """Shapes of a 3-hidden-layer MLP: in->64->64->64->out."""
    dims = [in_dim, HID, HID, HID, out_dim]
    shapes = []
    for a, b in zip(dims[:-1], dims[1:]):
        shapes.append((a, b))
        shapes.append((b,))
    return shapes


def flat_size(shapes):
    return sum(int(jnp.prod(jnp.asarray(s))) for s in shapes)


ACTOR_SHAPES = mlp_shapes(OBS, ACT)
CRITIC_SHAPES = mlp_shapes(STATE + M * ACT, 1)
PPO_SHAPES = mlp_shapes(STATE, PPO_ACTIONS + 1)

P_ACTOR = flat_size(ACTOR_SHAPES)
P_CRITIC = flat_size(CRITIC_SHAPES)
P_PPO = flat_size(PPO_SHAPES)


def unflatten(flat, shapes):
    """Static-slice a flat vector into the given shapes."""
    out, off = [], 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        out.append(flat[off:off + n].reshape(s))
        off += n
    return out


def mlp_apply(flat, shapes, x, out_act="none"):
    """Apply the MLP stored in ``flat``; ReLU hidden activations."""
    ps = unflatten(flat, shapes)
    h = x
    n_layers = len(ps) // 2
    for i in range(n_layers):
        w, b = ps[2 * i], ps[2 * i + 1]
        h = h @ w + b
        if i < n_layers - 1:
            h = jnp.maximum(h, 0.0)
    if out_act == "sigmoid":
        h = jax.nn.sigmoid(h)
    elif out_act == "tanh":
        h = jnp.tanh(h)
    return h


def init_mlp(key, shapes):
    """He-uniform init, biases zero, returned flat."""
    parts = []
    for s in shapes:
        key, sub = jax.random.split(key)
        if len(s) == 2:
            bound = jnp.sqrt(6.0 / s[0])
            parts.append(jax.random.uniform(sub, s, jnp.float32, -bound, bound).reshape(-1))
        else:
            parts.append(jnp.zeros(s, jnp.float32).reshape(-1))
    return jnp.concatenate(parts)


def adam_update(p, g, m, v, step):
    """One Adam step on flat vectors; returns (p', m', v')."""
    m = ADAM_B1 * m + (1.0 - ADAM_B1) * g
    v = ADAM_B2 * v + (1.0 - ADAM_B2) * g * g
    mhat = m / (1.0 - ADAM_B1 ** step)
    vhat = v / (1.0 - ADAM_B2 ** step)
    return p - LR * mhat / (jnp.sqrt(vhat) + ADAM_EPS), m, v


# ---------------------------------------------------------------------------
# Actor / critic forwards
# ---------------------------------------------------------------------------

def actor_apply(flat, obs):
    """π_m(O_m): [*, OBS] -> [*, ACT] in [0,1]^2 (Eq. 22)."""
    return mlp_apply(flat, ACTOR_SHAPES, obs, out_act="sigmoid")


def critic_apply(flat, state, actions_flat):
    """Q_m(S, A): centralized critic over global state + all actions."""
    x = jnp.concatenate([state, actions_flat], axis=-1)
    return mlp_apply(flat, CRITIC_SHAPES, x)[..., 0]


def actor_fwd(actor_flat, obs):
    """Per-env-step action selection for all M agents at once.

    actor_flat [M, P_ACTOR], obs [M, OBS] -> [M, ACT].
    """
    return (jax.vmap(actor_apply)(actor_flat, obs),)


# ---------------------------------------------------------------------------
# MADDPG train step (Algorithm 2 lines 15–20, all agents in one call)
# ---------------------------------------------------------------------------

def maddpg_train(
    actor, critic, t_actor, t_critic,
    m_a, v_a, m_c, v_c, step,
    s, a, r, s2, done, obs, obs2,
):
    """One full MADDPG update for all M agents.

    Args (all f32 unless noted):
      actor, t_actor   [M, P_ACTOR]      current / target actor params
      critic, t_critic [M, P_CRITIC]     current / target critic params
      m_a, v_a         [M, P_ACTOR]      Adam moments (actor)
      m_c, v_c         [M, P_CRITIC]     Adam moments (critic)
      step             []                Adam timestep (1-based, float)
      s, s2            [B, STATE]        global state / next state
      a                [B, M, ACT]       executed global action
      r                [B, M]            per-agent rewards (Eq. 24)
      done             [B, M]            terminal flags (0/1)
      obs, obs2        [B, M, OBS]       local observations / next

    Returns: (actor', critic', t_actor', t_critic', m_a', v_a', m_c',
              v_c', step', critic_loss [M], actor_loss [M]).
    """
    step = step + 1.0

    # Target actions for every agent from the *target* actor networks:
    # A' = {π'_1(O'_1), ..., π'_M(O'_M)}   (Eq. 30's A').
    a2 = jax.vmap(
        lambda p, o: actor_apply(p, o), in_axes=(0, 1), out_axes=1
    )(t_actor, obs2)                                  # [B, M, ACT]
    a2_flat = a2.reshape(a2.shape[0], M * ACT)
    a_flat = a.reshape(a.shape[0], M * ACT)

    def critic_loss_fn(c_flat, tc_flat, r_m, done_m):
        q_next = critic_apply(tc_flat, s2, a2_flat)
        y = r_m + (1.0 - done_m) * GAMMA * q_next      # Eq. (30)
        y = jax.lax.stop_gradient(y)
        q = critic_apply(c_flat, s, a_flat)
        return jnp.mean((q - y) ** 2)                  # Eq. (29)

    def actor_loss_fn(a_flat_m, c_flat, m_idx):
        my_obs = obs[:, m_idx, :]
        new_a_m = actor_apply(a_flat_m, my_obs)        # [B, ACT]
        # Replace agent m's slice of the joint action (Eq. 28).
        joint = a.at[:, m_idx, :].set(new_a_m).reshape(a.shape[0], M * ACT)
        q = critic_apply(c_flat, s, joint)
        return -jnp.mean(q)

    def update_one(m_idx, act_p, cri_p, tact_p, tcri_p, ma, va, mc, vc):
        r_m = r[:, m_idx]
        d_m = done[:, m_idx]
        closs, cgrad = jax.value_and_grad(critic_loss_fn)(cri_p, tcri_p, r_m, d_m)
        cri_p2, mc2, vc2 = adam_update(cri_p, cgrad, mc, vc, step)
        aloss, agrad = jax.value_and_grad(actor_loss_fn)(act_p, cri_p2, m_idx)
        act_p2, ma2, va2 = adam_update(act_p, agrad, ma, va, step)
        # Soft target updates (Eqs. 31–32).
        tact2 = TAU * act_p2 + (1.0 - TAU) * tact_p
        tcri2 = TAU * cri_p2 + (1.0 - TAU) * tcri_p
        return act_p2, cri_p2, tact2, tcri2, ma2, va2, mc2, vc2, closs, aloss

    outs = [update_one(m_idx, actor[m_idx], critic[m_idx], t_actor[m_idx],
                       t_critic[m_idx], m_a[m_idx], v_a[m_idx],
                       m_c[m_idx], v_c[m_idx])
            for m_idx in range(M)]

    stack = lambda i: jnp.stack([o[i] for o in outs])
    return (stack(0), stack(1), stack(2), stack(3), stack(4), stack(5),
            stack(6), stack(7), step, stack(8), stack(9))


# ---------------------------------------------------------------------------
# PPO (PTOM) — single agent over the global state
# ---------------------------------------------------------------------------

def ppo_apply(flat, s):
    """Policy logits over M servers + state value: [*, M+1]."""
    return mlp_apply(flat, PPO_SHAPES, s)


def ppo_fwd(flat, s):
    """Rollout forward: s [B, STATE] -> (logits [B, M], value [B])."""
    out = ppo_apply(flat, s)
    return out[..., :PPO_ACTIONS], out[..., PPO_ACTIONS]


def ppo_train(flat, m_p, v_p, step, s, act_onehot, old_logp, adv, ret):
    """One clipped-surrogate PPO epoch over a fixed batch.

    s [B, STATE], act_onehot [B, M], old_logp [B], adv [B], ret [B].
    Returns (flat', m', v', step', policy_loss, value_loss, entropy).
    """
    step = step + 1.0

    def loss_fn(p):
        logits, value = ppo_fwd(p, s)
        logp_all = jax.nn.log_softmax(logits, axis=-1)
        logp = jnp.sum(logp_all * act_onehot, axis=-1)
        ratio = jnp.exp(logp - old_logp)
        clipped = jnp.clip(ratio, 1.0 - PPO_CLIP, 1.0 + PPO_CLIP)
        pl_loss = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
        v_loss = jnp.mean((value - ret) ** 2)
        probs = jnp.exp(logp_all)
        entropy = -jnp.mean(jnp.sum(probs * logp_all, axis=-1))
        total = pl_loss + PPO_VCOEF * v_loss - PPO_ENTCOEF * entropy
        return total, (pl_loss, v_loss, entropy)

    (_, (pl_loss, v_loss, ent)), grad = jax.value_and_grad(
        loss_fn, has_aux=True)(flat)
    flat2, m2, v2 = adam_update(flat, grad, m_p, v_p, step)
    return flat2, m2, v2, step, pl_loss, v_loss, ent
