"""Synthetic citation-network datasets + the GEB binary format.

The paper evaluates on CiteSeer, Cora and PubMed (PyG downloads).  This
environment has no network access, so we substitute deterministic
synthetic citation graphs with the same vertex/edge/feature/class
statistics (see DESIGN.md §Substitutions — every experiment metric is a
*system* cost driven by topology and data sizes, which are matched):

  * |V|, |E|, feature dim (capped at 1500 per §6.1), class count match
    the real datasets exactly.
  * Edges come from a homophilous preferential-attachment process,
    reproducing the heavy-tailed degree distributions plotted in Fig. 5.
  * Features are class-correlated sparse bag-of-words, so the GNNs
    pre-trained at artifact-build time reach the paper's 60–80%
    node-classification accuracy band (§6.1) and serving runs a real
    workload.

GEB layout (little-endian; reader: ``rust/src/graph/geb.rs``):

    magic   b"GEB1"
    u32     N (vertices), u32 E (undirected edges),
    u32     F (real feature dim), u32 C (classes)
    u8×N    labels
    u32×(N+1)  feature CSR row pointers
    u16×nnz    feature column indices (value = 1.0, rows L2-normalized
               at load time)
    u32×2E     edge endpoint pairs (u, v), u < v
"""

import struct

import numpy as np

MAGIC = b"GEB1"

#: name -> (vertices, undirected edges, feature dim (capped), classes)
#: Real-dataset statistics from the paper §6.1; CiteSeer's 3703-dim
#: features are capped at 1500 ("dimensions greater than 1500 are
#: considered 1500").
SPECS = {
    "citeseer": (3327, 4552, 1500, 6),
    "cora": (2708, 5278, 1433, 7),
    "pubmed": (19717, 44324, 500, 3),
}
# NOTE: the paper quotes directed citation-link counts (9104, 10556,
# 88648); PyG stores each link twice.  We generate the undirected
# half-counts so |E| matches after symmetrization.

#: Homophily: probability a candidate endpoint of the same class is
#: accepted vs a different-class one (citation graphs are homophilous;
#: this is what lets 2-layer GNNs hit the paper's accuracy band).
P_SAME, P_DIFF = 0.9, 0.15
#: Bag-of-words sparsity: nonzeros per document ~ U[20, 60).
NNZ_LO, NNZ_HI = 20, 60
#: Fraction of a document's words drawn from its class signature.  Kept
#: moderate (plus overlapping signatures below) so pre-training lands in
#: the paper's 60–80% accuracy band instead of saturating.
SIGNATURE_FRAC = 0.5


def generate(name, seed=0xC0FFEE):
    """Generate one synthetic dataset; returns a dict of arrays."""
    n, e, f, c = SPECS[name]
    rng = np.random.default_rng((seed, hash(name) & 0xFFFFFFFF))
    labels = rng.integers(0, c, size=n).astype(np.uint8)

    edges = _preferential_attachment(rng, labels, n, e)

    # Class signatures: overlapping index pools per class (stride is
    # half the signature size, so adjacent classes share ~50% of their
    # vocabulary — this is what keeps the task in the 60–80% band).
    pool = rng.permutation(f)
    sig_size = max(f // c, 32)
    stride = max(sig_size // 2, 1)
    signatures = [
        np.concatenate([pool, pool])[(i * stride) % f:][:sig_size]
        for i in range(c)
    ]
    row_ptr = np.zeros(n + 1, dtype=np.uint32)
    cols = []
    for i in range(n):
        k = int(rng.integers(NNZ_LO, NNZ_HI))
        k_sig = int(k * SIGNATURE_FRAC)
        sig = signatures[labels[i]]
        chosen = set(rng.choice(sig, size=min(k_sig, len(sig)), replace=False).tolist())
        while len(chosen) < k:
            chosen.add(int(rng.integers(0, f)))
        idx = np.sort(np.fromiter(chosen, dtype=np.uint16))
        cols.append(idx)
        row_ptr[i + 1] = row_ptr[i] + len(idx)
    col_idx = np.concatenate(cols).astype(np.uint16)

    return {
        "n": n, "e": len(edges), "f": f, "c": c,
        "labels": labels,
        "row_ptr": row_ptr,
        "col_idx": col_idx,
        "edges": np.asarray(edges, dtype=np.uint32),
    }


def _preferential_attachment(rng, labels, n, e_target):
    """Homophilous Barabási–Albert-style growth.

    Each incoming vertex attaches ``m = ceil(E/N)``-ish edges to
    existing vertices sampled proportionally to degree, with a
    homophily accept/reject on class agreement.  Produces the
    heavy-tailed degree distribution of citation networks (Fig. 5).
    """
    m = max(1, round(e_target / n))
    # Seed clique over the first m+1 vertices.
    edges = set()
    endpoint_pool = []  # repeated endpoints ~ degree-proportional sampling
    seed_sz = m + 1
    for i in range(seed_sz):
        for j in range(i + 1, seed_sz):
            edges.add((i, j))
            endpoint_pool += [i, j]
    pool = np.asarray(endpoint_pool, dtype=np.int64)
    pool_list = pool.tolist()

    for v in range(seed_sz, n):
        targets = set()
        tries = 0
        want = m if len(edges) + (n - v) * m <= e_target + n else max(1, m - 1)
        while len(targets) < want and tries < 50 * m:
            tries += 1
            u = pool_list[int(rng.integers(0, len(pool_list)))]
            if u == v or u in targets:
                continue
            p = P_SAME if labels[u] == labels[v] else P_DIFF
            if rng.random() < p:
                targets.add(u)
        if not targets:  # fall back: uniform neighbor
            targets.add(int(rng.integers(0, v)))
        for u in targets:
            a, b = (u, v) if u < v else (v, u)
            edges.add((a, b))
            pool_list += [u, v]

    edges = sorted(edges)
    # Trim or top-up to hit the exact edge count.
    if len(edges) > e_target:
        keep = rng.choice(len(edges), size=e_target, replace=False)
        edges = [edges[i] for i in np.sort(keep)]
    while len(edges) < e_target:
        u = int(rng.integers(0, n))
        v = int(rng.integers(0, n))
        if u == v:
            continue
        a, b = min(u, v), max(u, v)
        if (a, b) not in set(edges):
            edges.append((a, b))
    return sorted(set(edges))[:e_target]


def write_geb(path, d):
    with open(path, "wb") as fh:
        fh.write(MAGIC)
        fh.write(struct.pack("<IIII", d["n"], d["e"], d["f"], d["c"]))
        fh.write(d["labels"].astype(np.uint8).tobytes())
        fh.write(d["row_ptr"].astype(np.uint32).tobytes())
        fh.write(d["col_idx"].astype(np.uint16).tobytes())
        fh.write(d["edges"].astype(np.uint32).tobytes())


def read_geb(path):
    """Python-side reader (tests + pretraining)."""
    with open(path, "rb") as fh:
        assert fh.read(4) == MAGIC, "bad GEB magic"
        n, e, f, c = struct.unpack("<IIII", fh.read(16))
        labels = np.frombuffer(fh.read(n), dtype=np.uint8)
        row_ptr = np.frombuffer(fh.read(4 * (n + 1)), dtype=np.uint32)
        nnz = int(row_ptr[-1])
        col_idx = np.frombuffer(fh.read(2 * nnz), dtype=np.uint16)
        edges = np.frombuffer(fh.read(8 * e), dtype=np.uint32).reshape(e, 2)
    return {"n": n, "e": e, "f": f, "c": c, "labels": labels,
            "row_ptr": row_ptr, "col_idx": col_idx, "edges": edges}


def dense_features(d, feat_pad, rows=None):
    """Expand sparse BoW rows to a dense, L2-row-normalized f32 matrix."""
    rows = range(d["n"]) if rows is None else rows
    out = np.zeros((len(rows), feat_pad), dtype=np.float32)
    rp, ci = d["row_ptr"], d["col_idx"]
    for k, i in enumerate(rows):
        idx = ci[rp[i]:rp[i + 1]].astype(np.int64)
        out[k, idx] = 1.0
        norm = np.linalg.norm(out[k])
        if norm > 0:
            out[k] /= norm
    return out


def adjacency_lists(d):
    adj = [[] for _ in range(d["n"])]
    for u, v in d["edges"]:
        adj[u].append(int(v))
        adj[v].append(int(u))
    return adj
