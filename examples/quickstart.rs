//! Quickstart: the GraphEdge pipeline in ~60 lines.
//!
//! 1. open the AOT artifacts, 2. sample an EC scenario from a citation
//! dataset, 3. optimize the graph layout with HiCut, 4. offload
//! greedily, 5. run real distributed GNN inference on the fleet, and
//! 6. print the paper's cost metrics.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use graphedge::coordinator::Controller;
use graphedge::drl::{baselines, Method};
use graphedge::net::SystemParams;
use graphedge::serving::{Fleet, GnnService};
use graphedge::util::rng::Rng;

fn main() -> graphedge::Result<()> {
    graphedge::util::logging::init();

    // The controller loads the PJRT runtime, manifest and datasets.
    let ctrl = Controller::new(SystemParams::default())?;
    println!("datasets: {:?}", ctrl.dataset_names());

    // A 120-user / 500-association scenario sampled from Cora.
    let mut rng = Rng::seed_from(7);
    let mut env = ctrl.make_env(Method::Greedy, "cora", 120, 500, &mut rng)?;
    println!(
        "scenario: {} users, {} associations, HiCut produced {} subgraphs \
         ({} cut edges)",
        env.users.active_count(),
        env.users.active_edges(),
        env.subgraph_size.len(),
        env.layout_cut_edges(),
    );

    // Offload every user (greedy nearest-server policy).
    baselines::run_greedy(&mut env);
    let cost = env.evaluate();
    println!(
        "cost: T_all={:.4}s I_all={:.4}J C={:.4} cross={:.1}Mb ({} edges)",
        cost.t_all(), cost.i_all(), cost.total(), cost.cross_mb, cost.cross_edges,
    );

    // Real GNN inference across the 4-server fleet.
    let svc = GnnService::load(&ctrl.rt, "gcn", "cora")?;
    let scenario = graphedge::graph::sample::Scenario {
        users: env.scenario.users.clone(),
        graph: env.users.graph().clone(),
    };
    let fleet = Fleet::new(&svc, &scenario, ctrl.dataset("cora")?);
    let users = &env.users;
    let report = fleet.infer_round(&env.offload, &|v| users.is_active(v), env.net.len(), None)?;
    println!(
        "inference: acc={:.3} halo_fetches={} ({:.1} Mb) exec={:.3}s batches={:?}",
        fleet.accuracy(&report, &|v| users.is_active(v)),
        report.halo_fetches,
        report.halo_mb,
        report.execute_s,
        report.batch_sizes,
    );
    Ok(())
}
