//! Incremental partition maintenance demo: churn → delta batch →
//! repair → offload → serve.
//!
//! Section 1 needs no artifacts: a 2000-user synthetic scenario churns
//! at the paper-default 20%/20% rate while the delta-driven
//! `IncrementalPartitioner` repairs the live layout, timed step by
//! step against a full HiCut recut of the same graph.
//!
//! Section 2 (when `make artifacts` has produced the AOT bundle)
//! drives the full online serving path with delta-driven repair.
//!
//! Run: `cargo run --release --example incremental_serving`

use graphedge::bench::{fmt_secs, Table};
use graphedge::graph::dynamic::{ChurnConfig, DynamicGraph};
use graphedge::graph::generate::preferential_attachment;
use graphedge::partition::hicut;
use graphedge::partition::incremental::{IncrementalConfig, IncrementalPartitioner};
use graphedge::util::rng::Rng;

fn main() -> graphedge::Result<()> {
    graphedge::util::logging::init();

    let n = 2000;
    let steps = 12;
    let mut rng = Rng::seed_from(17);
    let g = preferential_attachment(n, 6, &mut rng);
    let mut users = DynamicGraph::new(g, vec![1.0; n], 2000.0, &mut rng);
    users.record_deltas(true);
    let mut inc = IncrementalPartitioner::from_users(&users, IncrementalConfig::default());
    let churn = ChurnConfig::default();

    let mut t = Table::new(
        "incremental repair vs full recut (2000 users, 20%/20% churn)",
        &["step", "deltas", "repair", "full recut", "speedup", "inc cut", "full cut", "drift"],
    );
    let mut inc_s = 0.0;
    let mut full_s = 0.0;
    for step in 0..steps {
        users.step(&churn, &mut rng);
        let deltas = users.drain_deltas();

        let t0 = std::time::Instant::now();
        let stats = inc.apply(&users, &deltas);
        let dt_inc = t0.elapsed().as_secs_f64();

        let t0 = std::time::Instant::now();
        let full = hicut(users.graph(), |v| users.is_active(v));
        let dt_full = t0.elapsed().as_secs_f64();

        inc_s += dt_inc;
        full_s += dt_full;
        let full_cut = full.cut_edges(users.graph());
        t.row(vec![
            step.to_string(),
            stats.deltas.to_string(),
            fmt_secs(dt_inc),
            fmt_secs(dt_full),
            format!("{:.1}x", dt_full / dt_inc.max(1e-9)),
            stats.cut_edges.to_string(),
            full_cut.to_string(),
            format!(
                "{:+.1}%",
                100.0 * (stats.cut_edges as f64 - full_cut as f64)
                    / full_cut.max(1) as f64
            ),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\nrepair {}/step vs full recut {}/step — {:.1}x faster; \
         {} drift fallbacks, {} local recuts over {steps} steps",
        fmt_secs(inc_s / steps as f64),
        fmt_secs(full_s / steps as f64),
        full_s / inc_s.max(1e-9),
        inc.full_recuts.saturating_sub(1), // constructor's reference cut
        inc.local_recuts,
    );
    println!(
        "layout steps/sec: incremental {:.1} vs full {:.1}",
        steps as f64 / inc_s.max(1e-9),
        steps as f64 / full_s.max(1e-9),
    );

    // Section 2: the full serving path (requires AOT artifacts).
    match graphedge::coordinator::Controller::new(graphedge::net::SystemParams::default()) {
        Ok(ctrl) => {
            graphedge::serving::serve_dynamic(
                &ctrl, "cora", "gcn", 300, 1800, 8, 40, 5, true, 2,
            )?;
        }
        Err(e) => {
            println!("\n(skipping fleet serving section: {e:#})");
            println!("run `make artifacts` to enable the GNN serving demo");
        }
    }
    Ok(())
}
