//! Dynamic adaptation demo (the paper's headline claim): the scenario
//! churns every time step — users join/leave, move, and re-associate —
//! and the EC controller re-perceives the layout, re-runs HiCut and
//! re-offloads.  DRLGO's cost is compared against GM/RM step by step.
//!
//! Run: `cargo run --release --example dynamic_scenario`

use graphedge::bench::Table;
use graphedge::coordinator::Controller;
use graphedge::drl::{baselines, MaddpgConfig, Method};
use graphedge::net::SystemParams;
use graphedge::util::rng::Rng;

fn main() -> graphedge::Result<()> {
    graphedge::util::logging::init();
    let ctrl = Controller::new(SystemParams::default())?;

    println!("training DRLGO (40 episodes, 150 users)...");
    let cfg = MaddpgConfig { episodes: 40, ..MaddpgConfig::default() };
    let (mut drlgo, _, _) = ctrl.train_drlgo("cora", false, 150, 900, &cfg)?;

    let mut rng = Rng::seed_from(31);
    let mut envs = vec![
        ctrl.make_env(Method::Drlgo, "cora", 150, 900, &mut rng)?,
        ctrl.make_env(Method::Greedy, "cora", 150, 900, &mut rng)?,
        ctrl.make_env(Method::Random, "cora", 150, 900, &mut rng)?,
    ];

    let mut t = Table::new(
        "dynamic scenario: per-step system cost (20% churn per step)",
        &["step", "active users", "subgraphs", "DRLGO", "GM", "RM"],
    );
    for step in 0..10 {
        // Scenario dynamics: §3.2's three kinds of change.
        for env in &mut envs {
            env.mutate(&mut rng);
        }
        drlgo.policy_offload(&mut envs[0])?;
        baselines::run_greedy(&mut envs[1]);
        envs[2].reset();
        baselines::run_random(&mut envs[2], &mut rng);
        t.row(vec![
            step.to_string(),
            envs[0].users.active_count().to_string(),
            envs[0].subgraph_size.len().to_string(),
            format!("{:.3}", envs[0].evaluate().total()),
            format!("{:.3}", envs[1].evaluate().total()),
            format!("{:.3}", envs[2].evaluate().total()),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}
