//! Partition explorer: HiCut vs the max-flow min-cut baseline across
//! graph families (uniform random, preferential attachment, clustered
//! communities) — cut quality and runtime side by side.
//!
//! Run: `cargo run --release --example partition_explorer`

use graphedge::bench::{fmt_secs, Table};
use graphedge::graph::generate::{preferential_attachment, random_weights, uniform_random};
use graphedge::graph::Graph;
use graphedge::partition::{hicut, mincut_partition};
use graphedge::util::rng::Rng;

/// Dense communities joined by sparse bridges.
fn clustered(communities: usize, size: usize, rng: &mut Rng) -> Graph {
    let mut g = Graph::new(communities * size);
    for c in 0..communities {
        let base = c * size;
        for i in 0..size {
            for j in (i + 1)..size {
                if rng.chance(0.4) {
                    g.add_edge(base + i, base + j);
                }
            }
        }
    }
    for c in 0..communities - 1 {
        g.add_edge(c * size, (c + 1) * size);
    }
    g
}

fn main() {
    let mut rng = Rng::seed_from(5);
    let graphs: Vec<(&str, Graph)> = vec![
        ("uniform(2000, 20000)", uniform_random(2000, 20_000, &mut rng)),
        ("pref-attach(2000, d=10)", preferential_attachment(2000, 10, &mut rng)),
        ("clustered(40 x 50)", clustered(40, 50, &mut rng)),
    ];
    let mut t = Table::new(
        "HiCut vs min-cut across graph families",
        &["graph", "method", "time", "subgraphs", "cut edges", "locality"],
    );
    for (name, g) in &graphs {
        let w = random_weights(g, 1, 100, &mut rng);
        let t0 = std::time::Instant::now();
        let hp = hicut(g, &|_| true);
        let t_hi = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let mp = mincut_partition(g, &w, 25, &mut rng);
        let t_mc = t0.elapsed().as_secs_f64();
        for (method, time, p) in
            [("HiCut", t_hi, &hp), ("min-cut [36]", t_mc, &mp)]
        {
            t.row(vec![
                name.to_string(),
                method.into(),
                fmt_secs(time),
                p.len().to_string(),
                p.cut_edges(g).to_string(),
                format!("{:.3}", p.locality(g)),
            ]);
        }
    }
    print!("{}", t.render());
}
