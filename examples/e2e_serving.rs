//! End-to-end driver (the repo's headline validation run, recorded in
//! EXPERIMENTS.md):
//!
//!   1. train DRLGO (HiCut + MADDPG via the AOT `maddpg_train`
//!      executable) on a dynamic PubMed scenario,
//!   2. load the pre-trained GCN artifact and serve a stream of
//!      batched inference requests through the router + fleet,
//!   3. report training convergence, system cost vs the GM/RM
//!      baselines, and serving latency/throughput.
//!
//! Run: `make artifacts && cargo run --release --example e2e_serving`
//! (smaller/larger: E2E_EPISODES / E2E_REQUESTS env vars).

use graphedge::bench::{fmt_secs, Table};
use graphedge::coordinator::Controller;
use graphedge::drl::{baselines, MaddpgConfig, Method};
use graphedge::net::SystemParams;
use graphedge::serving::serve_run;
use graphedge::util::metrics::GLOBAL as METRICS;
use graphedge::util::rng::Rng;

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() -> graphedge::Result<()> {
    graphedge::util::logging::init();
    let episodes = env_usize("E2E_EPISODES", 60);
    let requests = env_usize("E2E_REQUESTS", 1000);
    let (users, assocs) = (300, 4800);

    let ctrl = Controller::new(SystemParams::default())?;

    // ---- 1. train DRLGO on a churning scenario ----
    println!("[1/3] training DRLGO: {episodes} episodes on pubmed (N={users}, E={assocs})");
    let t0 = std::time::Instant::now();
    let cfg = MaddpgConfig { episodes, ..MaddpgConfig::default() };
    let (mut drlgo, _env, curve) = ctrl.train_drlgo("pubmed", false, users, assocs, &cfg)?;
    println!(
        "    trained in {} — reward {:.1} → {:.1} (cost {:.2} → {:.2})",
        fmt_secs(t0.elapsed().as_secs_f64()),
        curve.first().unwrap().reward,
        curve.last().unwrap().reward,
        curve.first().unwrap().system_cost,
        curve.last().unwrap().system_cost,
    );

    // ---- 2. offloading quality vs baselines on fresh scenarios ----
    println!("[2/3] evaluating offloading policies (3 fresh scenarios each)");
    let mut table = Table::new(
        "e2e: system cost (mean of 3 scenarios, pubmed N=300 E=4800)",
        &["method", "T_all (s)", "I_all (J)", "C", "cross-Mb", "decision"],
    );
    for method in [Method::Drlgo, Method::Greedy, Method::Random] {
        let (mut t_all, mut i_all, mut c, mut cross, mut dec) = (0.0, 0.0, 0.0, 0.0, 0.0);
        for rep in 0..3u64 {
            let mut rng = Rng::seed_from(1000 + rep);
            let mut env = ctrl.make_env(method, "pubmed", users, assocs, &mut rng)?;
            let t0 = std::time::Instant::now();
            match method {
                Method::Drlgo => drlgo.policy_offload(&mut env)?,
                Method::Greedy => baselines::run_greedy(&mut env),
                Method::Random => baselines::run_random(&mut env, &mut rng),
                _ => unreachable!(),
            }
            dec += t0.elapsed().as_secs_f64() / 3.0;
            let cost = env.evaluate();
            t_all += cost.t_all() / 3.0;
            i_all += cost.i_all() / 3.0;
            c += cost.total() / 3.0;
            cross += cost.cross_mb / 3.0;
        }
        table.row(vec![
            method.name().into(),
            format!("{t_all:.4}"),
            format!("{i_all:.4}"),
            format!("{c:.4}"),
            format!("{cross:.1}"),
            fmt_secs(dec),
        ]);
    }
    print!("{}", table.render());

    // ---- 3. online batched serving through the router + fleet ----
    println!("[3/3] serving {requests} batched requests (gcn/pubmed)");
    let stats = serve_run(&ctrl, "pubmed", "gcn", 200, 1200, requests, 5)?;
    println!("    requests      {}", stats.requests);
    println!("    batches       {} (mean size {:.1})", stats.batches, stats.mean_batch);
    println!("    throughput    {:.1} req/s", stats.requests as f64 / stats.total_s);
    println!("    latency p50   {:.3} ms", stats.latency_p50_s * 1e3);
    println!("    latency p99   {:.3} ms", stats.latency_p99_s * 1e3);
    println!("    accuracy      {:.3}", stats.accuracy);
    print!("{}", METRICS.report());
    Ok(())
}
